"""repro.zo: sampler contracts (seed replay, estimator bias, variance),
shim equivalence with the original core.mezo, and the gradient-quality
probe machinery."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.zo import (SAMPLERS, BlockwiseSampler, DenseSampler,
                      LowRankSampler, SparseSampler, get_sampler, perturb,
                      spsa_grad_from_loss)


def _toy_tree(key=None, zeros=False):
    """LoRA-shaped trainable tree (stacked [L, ., .] a/b factor pairs)."""
    shapes = {"blocks": {"q": {"a": (4, 6, 3), "b": (4, 3, 6)},
                         "up": {"a": (4, 6, 5), "b": (4, 5, 6)}}}

    def make(path_key, shape):
        if zeros:
            return jnp.zeros(shape, jnp.float32)
        return jax.random.normal(path_key, shape, jnp.float32)

    key = key if key is not None else jax.random.PRNGKey(0)
    leaves, treedef = jax.tree_util.tree_flatten(shapes,
                                                 is_leaf=lambda x:
                                                 isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [make(k, s) for k, s in zip(keys, leaves)])


def _all_samplers():
    return [(name, get_sampler(name)) for name in sorted(SAMPLERS)]


# ------------------------------------------------------ sampler contracts


@pytest.mark.parametrize("name", sorted(SAMPLERS))
def test_seed_replay_is_bit_exact(name):
    """z is a pure function of (key, train): regenerating it — which is how
    perturb/unperturb/gradient all obtain it, nothing is ever stored — gives
    bit-identical arrays."""
    sampler = get_sampler(name)
    train = _toy_tree()
    key = jax.random.PRNGKey(42)
    z1, z2 = sampler.sample(key, train), sampler.sample(key, train)
    for a, b in zip(jax.tree_util.tree_leaves(z1),
                    jax.tree_util.tree_leaves(z2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different key -> different direction
    z3 = sampler.sample(jax.random.PRNGKey(43), train)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(z1),
                               jax.tree_util.tree_leaves(z3)))


@pytest.mark.parametrize("name", sorted(SAMPLERS))
def test_perturb_unperturb_round_trip(name):
    """±ε applications of the regenerated z cancel: bit-exact where IEEE
    guarantees it (x − x ≡ 0), ≤1e-6 on arbitrary parameter values."""
    sampler = get_sampler(name)
    key = jax.random.PRNGKey(7)

    zeros = _toy_tree(zeros=True)
    z = sampler.sample(key, zeros)
    back = perturb(perturb(zeros, z, +1e-3), z, -1e-3)
    for leaf in jax.tree_util.tree_leaves(back):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))

    train = _toy_tree()
    z = sampler.sample(key, train)
    back = perturb(perturb(train, z, +1e-3), z, -1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(train)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_sparse_sampler_masks_top_rho_by_magnitude():
    train = _toy_tree()
    z = SparseSampler(rho=0.10).sample(jax.random.PRNGKey(0), train)
    for zi, pi in zip(jax.tree_util.tree_leaves(z),
                      jax.tree_util.tree_leaves(train)):
        nz = np.asarray(zi) != 0
        assert 0.05 <= nz.mean() <= 0.20  # ~top 10% (quantile ties aside)
        # support sits on the largest-|w| coordinates
        mag = np.abs(np.asarray(pi))
        assert mag[nz].min() >= np.quantile(mag, 0.80)
    # degenerate all-equal-magnitude leaf (LoRA B at init): dense fallback
    zeros = _toy_tree(zeros=True)
    z0 = SparseSampler(rho=0.10).sample(jax.random.PRNGKey(0), zeros)
    assert all((np.asarray(zi) != 0).all()
               for zi in jax.tree_util.tree_leaves(z0))


def test_lowrank_sampler_is_rank_one_per_block():
    train = _toy_tree()
    z = LowRankSampler().sample(jax.random.PRNGKey(0), train)
    for zi in jax.tree_util.tree_leaves(z):
        for l in range(zi.shape[0]):
            assert np.linalg.matrix_rank(np.asarray(zi[l]), tol=1e-5) == 1


def test_lowrank_cross_scale_pairs_per_list_element():
    """List-indexed pytree levels (hybrid 'tail' layout) must pair each
    element's a/b factors separately — not last-write-wins merge them."""
    from repro.zo.samplers import _paired_factor_scales

    tail = [{"q": {"a": jnp.full((4, 2), float(i + 1)),
                   "b": jnp.full((2, 4), 10.0 * (i + 1))}}
            for i in range(3)]
    scales = _paired_factor_scales({"tail": tail})
    # leaves order: tail[0].a, tail[0].b, tail[1].a, ... — each a-leaf's
    # scale is its own layer's B RMS (10(i+1)), not the last layer's
    a_scales = [float(s) for s in scales[::2]]
    b_scales = [float(s) for s in scales[1::2]]
    np.testing.assert_allclose(a_scales, [10.0, 20.0, 30.0], rtol=1e-6)
    np.testing.assert_allclose(b_scales, [1.0, 2.0, 3.0], rtol=1e-6)


def test_blockwise_sampler_touches_one_block():
    train = _toy_tree()
    z = BlockwiseSampler().sample(jax.random.PRNGKey(3), train)
    for zi in jax.tree_util.tree_leaves(z):
        live = [l for l in range(zi.shape[0])
                if np.abs(np.asarray(zi[l])).sum() > 0]
        assert len(live) == 1


# ------------------------------------------------- estimator contracts


def _quadratic(target):
    def loss(t):
        sq = jax.tree_util.tree_map(lambda p, q: jnp.sum((p - q) ** 2),
                                    t, target)
        return 0.5 * sum(jax.tree_util.tree_leaves(sq))
    return loss


@pytest.mark.parametrize("name", sorted(SAMPLERS))
def test_estimate_positively_correlates_on_toy_quadratic(name):
    """E[ĝ]·g > 0 for every sampler: the SPSA estimate is an ascent-direction
    estimator (E[ĝ] = E[zzᵀ]∇L with E[zzᵀ] PSD and full/masked support)."""
    sampler = get_sampler(name)
    train = _toy_tree(jax.random.PRNGKey(1))
    target = _toy_tree(jax.random.PRNGKey(2))
    loss = _quadratic(target)
    g_true = jax.grad(loss)(train)

    est = jax.jit(functools.partial(spsa_grad_from_loss, loss, train,
                                    sampler=sampler, eps=1e-3))
    acc = None
    n = 200
    for i in range(n):
        _, g = est(jax.random.PRNGKey(100 + i))
        acc = g if acc is None else jax.tree_util.tree_map(jnp.add, acc, g)
    dots = jax.tree_util.tree_map(
        lambda a, b: jnp.sum((a / n) * b), acc, g_true)
    total = sum(float(x) for x in jax.tree_util.tree_leaves(dots))
    norm = sum(float(jnp.sum(x ** 2))
               for x in jax.tree_util.tree_leaves(g_true))
    assert total / norm > 0.05, f"{name}: E[ĝ]·g = {total/norm:.4f}"


def test_multi_query_averaging_reduces_variance_monotonically():
    train = _toy_tree(jax.random.PRNGKey(1))
    target = _toy_tree(jax.random.PRNGKey(2))
    loss = _quadratic(target)
    sampler = DenseSampler()

    def estimator_variance(queries, trials=48):
        est = jax.jit(functools.partial(spsa_grad_from_loss, loss, train,
                                        sampler=sampler, queries=queries))
        flat = []
        for i in range(trials):
            _, g = est(jax.random.PRNGKey(1000 * queries + i))
            flat.append(np.concatenate(
                [np.asarray(x).ravel()
                 for x in jax.tree_util.tree_leaves(g)]))
        flat = np.stack(flat)
        return float(flat.var(axis=0).mean())

    v1, v4, v16 = (estimator_variance(k) for k in (1, 4, 16))
    assert v1 > v4 > v16
    assert v4 < 0.5 * v1 and v16 < 0.5 * v4  # ~1/k scaling, with slack


# ---------------------------------------------------- shim equivalence


def _setup_model():
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("qwen2.5-0.5b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    return cfg, params, {"tokens": tokens, "labels": tokens}


def test_core_mezo_shim_matches_original_implementation():
    """core.mezo delegates to repro.zo; results must equal the original
    inline implementation (reproduced here verbatim) to ≤1e-6 — they are in
    fact bit-identical (same leaf order, key splits and op sequence)."""
    from repro.api.policy import PLAIN
    from repro.core import mezo
    from repro.models import model as model_lib

    def original_spsa_grad(params, cfg, batch, key, eps=1e-3):
        def _perturb(train, key, eps_signed):
            leaves, treedef = jax.tree_util.tree_flatten(train)
            keys = jax.random.split(key, len(leaves))
            out = [p + eps_signed * jax.random.normal(k, p.shape, p.dtype)
                   for p, k in zip(leaves, keys)]
            return jax.tree_util.tree_unflatten(treedef, out)

        train, frozen = model_lib.split_params(params)

        def loss(t):
            return model_lib.loss_fn(model_lib.merge_params(t, frozen), cfg,
                                     batch, policy=PLAIN)

        l_plus = loss(_perturb(train, key, +eps))
        l_minus = loss(_perturb(train, key, -eps))
        proj = (l_plus - l_minus) / (2.0 * eps)
        leaves, treedef = jax.tree_util.tree_flatten(train)
        keys = jax.random.split(key, len(leaves))
        grads = [proj.astype(p.dtype) * jax.random.normal(k, p.shape, p.dtype)
                 for p, k in zip(leaves, keys)]
        return 0.5 * (l_plus + l_minus), jax.tree_util.tree_unflatten(
            treedef, grads)

    cfg, params, batch = _setup_model()
    key = jax.random.PRNGKey(9)
    l_new, g_new = mezo.spsa_grad(params, cfg, batch, key)
    l_old, g_old = original_spsa_grad(params, cfg, batch, key)
    np.testing.assert_allclose(float(l_new), float(l_old), atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g_new),
                    jax.tree_util.tree_leaves(g_old)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ------------------------------------------------- gradquality + engines


def test_gradquality_probe_reports_global_and_per_layer():
    from repro.zo import gradquality

    cfg, params, batch = _setup_model()
    res = gradquality.probe("mezo", params, cfg, batch,
                            jax.random.PRNGKey(3))
    assert set(res["global"]) == {"cosine_sim", "sign_agree", "rel_error"}
    assert -1.0 <= res["global"]["cosine_sim"] <= 1.0
    assert len(res["per_layer"]) == cfg.n_layers


def test_zo_engine_trains_end_to_end(tmp_path):
    """A structured ZO engine runs through the Trainer facade (spec → fit),
    touching only LoRA params — no edits to launch/ or models/."""
    from repro.api import Trainer, TrainSpec
    from repro.models import model as M

    spec = TrainSpec(arch="qwen2.5-0.5b", reduced=True, engine="mezo_sparse",
                     lr=1e-2, steps=2, seq=16, batch=2,
                     ckpt_dir=str(tmp_path / "ckpt"))
    tr = Trainer.from_spec(spec)
    params0, opt0 = tr.init_state()
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                tr.cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    params1, _, loss = tr.step_fn(params0, opt0, batch)
    assert np.isfinite(float(loss))
    mask = M.trainable_mask(params0)
    for m, (a, b) in zip(jax.tree_util.tree_leaves(mask),
                         zip(jax.tree_util.tree_leaves(params0),
                             jax.tree_util.tree_leaves(params1))):
        if not m:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multi_query_engine_key_depends_on_spec_seed(tmp_path):
    from repro.api import TrainSpec, Trainer

    cfg, params0, batch = _setup_model()

    def one(seed):
        spec = TrainSpec(engine="mezo_avg4", seed=seed, lr=1e-2, steps=1,
                         ckpt_dir=str(tmp_path / f"s{seed}"))
        tr = Trainer.from_spec(spec, cfg=cfg)
        p, _, _ = tr.step_fn(params0, tr.opt.init(params0), batch)
        return np.concatenate([np.asarray(x).ravel()
                               for x in jax.tree_util.tree_leaves(p)])

    assert np.array_equal(one(0), one(0))
    assert not np.array_equal(one(0), one(5))
