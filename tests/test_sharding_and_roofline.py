"""Sharding spec construction + HLO roofline analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.launch import sharding as sh
from repro.roofline.hlo_parse import HloModule, analyze_text
from repro.roofline.analysis import collective_bytes, model_flops
from repro.configs.base import SHAPES


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_rank_matches(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["m"]).init_params(
            jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(cfg, params)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (path, leaf.shape, spec)


def test_divisibility_guard(fake_mesh):
    cfg = get_config("whisper-tiny")  # vocab 51865: not divisible by 16
    mesh = fake_mesh(16, 16)
    params = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["m"]).init_params(
            jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(cfg, params, mesh)
    head_spec = specs["embed"]["head"]
    assert head_spec == P(None, None)  # guarded off
    # q projection (384 -> 384) IS divisible: stays sharded
    q_spec = specs["blocks"]["attn"]["q"]["w"]
    assert q_spec[-1] == "model"


def test_moe_expert_parallel_specs():
    cfg = get_config("olmoe-1b-7b")
    params = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["m"]).init_params(
            jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(cfg, params)
    # stacked [L, E, d, f] expert weights: E dim sharded on model
    w_spec = specs["blocks"]["moe"]["gate"]["w"]
    assert tuple(w_spec) == (None, "model", None, None)


def test_batch_spec_fallbacks(fake_mesh):
    mesh = fake_mesh(16, 16)
    spec = tuple(sh.batch_spec(mesh, 256))
    assert spec in ((("data",),), ("data",))  # P may normalize 1-tuples
    assert tuple(sh.batch_spec(mesh, 1)) == ()


# ------------------------------------------------------------------ roofline
def test_hlo_analyzer_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=9)[0]

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    t = analyze_text(compiled.as_text())
    assert t.flops == pytest.approx(2 * 128**3 * 9, rel=1e-6)


def test_hlo_analyzer_nested_while():
    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        return jax.lax.scan(outer, x, None, length=4)[0]

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    t = analyze_text(compiled.as_text())
    assert t.flops == pytest.approx(2 * 64**3 * 12, rel=1e-6)


def test_collective_regex():
    text = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[16,64]{1,0} %x), replica_groups={}
  %ar.1 = f32[256,256]{1,0} all-reduce(f32[256,256]{1,0} %y), to_apply=%sum
"""
    out = collective_bytes(text)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 256 * 256 * 4


def test_model_flops_accounting():
    cfg = get_config("olmoe-1b-7b")
    dense_equiv = get_config("granite-8b")
    # MoE active < total
    assert cfg.n_active_params() < cfg.n_params()
    tr = model_flops(cfg, SHAPES["train_4k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > de  # decode touches 1 token per sequence
    assert model_flops(dense_equiv, SHAPES["train_4k"]) == pytest.approx(
        6 * dense_equiv.n_params() * 256 * 4096)
