"""Chaos-hardening tests: fault injection, degradation ladder, step guard,
checkpoint quarantine/fallback, and the supervised ResilientLoop — unit
level plus a fault-injection matrix through the ``Trainer.fit`` facade."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Trainer, TrainSpec
from repro.checkpoint import Checkpointer, save_checkpoint
from repro.data import make_batch_iterator
from repro.runtime.degrade import (DegradationLadder, LadderExhausted,
                                   carry_opt_state, predicted_peak_mb)
from repro.runtime.fault_tolerance import (ResilientLoop, StragglerPolicy,
                                           run_resilient)
from repro.runtime.faults import (FaultInjector, FaultPlan, InjectedOOM,
                                  corrupt_latest_checkpoint, is_oom_error)
from repro.runtime.guard import GuardExhausted, StepGuard


# ---------------------------------------------------------------- FaultPlan
def test_fault_plan_parse_round_trip():
    text = "oom@4,corrupt@8,crash@9,nan@14,stall@18:1.5"
    plan = FaultPlan.parse(text)
    assert len(plan.events) == 5
    assert plan.to_string() == text
    assert FaultPlan.parse(plan.to_string()) == plan
    stall = [e for e in plan.events if e.kind == "stall"][0]
    assert stall.arg == 1.5


def test_fault_plan_same_step_ordering():
    # corrupt must fire before crash at the same step, or the crash's
    # restore would never see the poisoned checkpoint
    plan = FaultPlan.parse("crash@9,corrupt@9")
    assert [e.kind for e in plan.events] == ["corrupt", "crash"]


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="bad fault entry"):
        FaultPlan.parse("meteor@3")


def test_fault_plan_seeded_is_deterministic():
    a = FaultPlan.seeded(seed=7, total_steps=50)
    b = FaultPlan.seeded(seed=7, total_steps=50)
    c = FaultPlan.seeded(seed=8, total_steps=50)
    assert a == b and a.to_string() == b.to_string()
    assert a != c
    assert len(a.events) == 5
    assert len({e.step for e in a.events}) == 5          # distinct steps
    assert all(0 < e.step < 50 for e in a.events)


def test_fault_plan_from_string_random():
    plan = FaultPlan.from_string("random:3", total_steps=30, seed=1)
    assert len(plan.events) == 3
    assert plan == FaultPlan.from_string("random:3", total_steps=30, seed=1)


def test_is_oom_error_classification():
    assert is_oom_error(InjectedOOM("RESOURCE_EXHAUSTED: boo"))
    assert is_oom_error(MemoryError())
    assert is_oom_error(RuntimeError("RESOURCE_EXHAUSTED: hbm"))
    assert not is_oom_error(RuntimeError("device lost"))


def test_injector_fires_each_event_once(tmp_path):
    inj = FaultInjector(FaultPlan.parse("oom@2"), ckpt_dir=str(tmp_path))
    inj.before_step(0)
    with pytest.raises(InjectedOOM):
        inj.before_step(2)
    inj.before_step(2)          # a rewound replay must not re-fire it
    assert inj.summary() == {"oom": 1} and inj.exhausted


def test_spec_validates_fault_plan_early():
    with pytest.raises(ValueError, match="bad fault entry"):
        TrainSpec(inject_faults="nonsense").validate()
    TrainSpec(inject_faults="oom@4,nan@7").validate()


# ---------------------------------------------------------------- StepGuard
def test_guard_rejects_nonfinite_and_exhausts_budget():
    g = StepGuard(budget=2)
    assert g.observe(1.0) == "accept"
    assert g.observe(float("nan")) == "reject"
    assert g.observe(float("inf")) == "reject"
    with pytest.raises(GuardExhausted):
        g.observe(float("nan"))


def test_guard_rejects_loss_spike_after_warmup():
    g = StepGuard(budget=4, spike_factor=10.0, warmup=3)
    for _ in range(3):
        assert g.observe(1.0) == "accept"
    assert g.observe(50.0) == "reject"       # 50 > 10 x EWMA(1.0)
    assert g.observe(1.1) == "accept"        # baseline not poisoned
    assert g.rejected == 1


def test_guard_rejects_update_norm_spike():
    g = StepGuard(budget=4, spike_factor=10.0, warmup=2)
    assert g.observe(1.0, update_norm=0.1) == "accept"
    assert g.observe(1.0, update_norm=0.1) == "accept"
    assert g.observe(1.0, update_norm=5.0) == "reject"


# ---------------------------------------------------------------- straggler
def test_straggler_warmup_discards_compile_step():
    # a 100x jit-compile first step must not seed the EWMA baseline
    sp = StragglerPolicy(factor=3.0, consecutive_limit=2, warmup=1)
    assert sp.observe(10.0) == "ok"          # compile step, discarded
    assert sp.observe(0.1) == "ok"           # seeds the baseline
    assert sp.observe(0.11) == "ok"
    assert sp.observe(1.0) == "slow"
    sp.reset()
    assert sp.observe(10.0) == "ok" and sp.mean is None


# ------------------------------------------------- ladder + opt-state carry
def test_ladder_walks_validated_rungs():
    spec = TrainSpec(engine="mesp_pallas", batch=4, seq=256)
    rungs = dict((r, c) for c, r in DegradationLadder().candidates(spec))
    assert rungs["halve_batch"].batch == 2
    assert rungs["engine_mesp"].engine == "mesp"
    assert rungs["quantize_int8"].quantize == "int8"
    assert rungs["truncate_seq"].seq == 128
    base = predicted_peak_mb(spec)
    if base is not None:     # memsim present: every rung must not grow peak
        for cand in rungs.values():
            assert predicted_peak_mb(cand) <= base + 1e-6


def test_ladder_offers_int4_after_int8():
    """The packed rung is only reachable *from* int8 (one notch of
    quantization error at a time), and is the sole rung left at the
    batch/seq/engine floor."""
    spec = TrainSpec(engine="mesp_seq", batch=1, seq=32, quantize="int8")
    rungs = dict((r, c) for c, r in
                 DegradationLadder(min_batch=1, min_seq=32).candidates(spec))
    assert set(rungs) == {"quantize_int4"}
    assert rungs["quantize_int4"].quantize == "int4"
    # never offered straight from an unquantized spec
    fresh = TrainSpec(engine="mesp_pallas", batch=4, seq=256)
    assert "quantize_int4" not in {
        r for _, r in DegradationLadder().candidates(fresh)}


def test_ladder_exhausts_at_floor():
    spec = TrainSpec(engine="mesp_seq", batch=1, seq=32, quantize="int4")
    with pytest.raises(LadderExhausted):
        list(DegradationLadder(min_batch=1, min_seq=32).candidates(spec))


def test_carry_opt_state_across_int8_rewrite():
    from repro.core.quant import quantize_params

    params = {"blk": {"w": jnp.ones((4, 4)), "a": jnp.ones((4, 2)),
                      "b": jnp.zeros((2, 4))}}
    mom = jax.tree_util.tree_map(lambda x: x * 2.0, params)
    opt_state = {"step": jnp.array(3, jnp.int32), "m": mom}
    qp = quantize_params(params, "int8")
    out = carry_opt_state(opt_state, params, qp)
    assert int(out["step"]) == 3
    # LoRA moments carried verbatim; rewritten frozen slots drop to None
    np.testing.assert_array_equal(out["m"]["blk"]["a"], mom["blk"]["a"])
    np.testing.assert_array_equal(out["m"]["blk"]["b"], mom["blk"]["b"])
    assert out["m"]["blk"]["w"]["q"] is None
    assert out["m"]["blk"]["w"]["scale"] is None


# ------------------------------------------------------- loop satellites
def _counting_loop(tmp_path, fail_calls, total_steps=8, max_retries=1,
                   interval=2):
    it = make_batch_iterator(50, 4, 2, n_tokens=2048)
    ckpt = Checkpointer(str(tmp_path), interval=interval)
    calls = {"n": 0}

    def step_fn(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] in fail_calls:
            raise RuntimeError(f"boom at call {calls['n']}")
        return params + 1, opt_state, float(params)

    return ResilientLoop(step_fn, lambda: (jnp.array(0.0), None), it, ckpt,
                         total_steps, max_retries=max_retries,
                         backoff_base=0.0)


def test_retry_budget_resets_after_success(tmp_path):
    # two failures separated by successes: with max_retries=1 both must be
    # absorbed (the old accounting never reset and killed the run)
    loop = _counting_loop(tmp_path, fail_calls={3, 8}, max_retries=1)
    params, _, results, counters = loop.run()
    assert results[-1].step == 8
    assert counters.step_failures == 2
    assert float(params) == 8.0


def test_consecutive_failures_still_raise(tmp_path):
    loop = _counting_loop(tmp_path, fail_calls={3, 4, 5}, max_retries=2)
    with pytest.raises(RuntimeError, match="boom"):
        loop.run()


def test_forced_final_checkpoint_on_exit(tmp_path):
    # total_steps % interval != 0: the loop must still leave a final
    # checkpoint at the last step
    loop = _counting_loop(tmp_path, fail_calls=set(), total_steps=7,
                          interval=5)
    loop.run()
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 7


def test_run_resilient_wrapper_keeps_legacy_contract(tmp_path):
    it = make_batch_iterator(50, 4, 2, n_tokens=2048)
    ckpt = Checkpointer(str(tmp_path), interval=100)
    out = run_resilient(lambda p, o, b: (p, o, 0.0),
                        lambda: (jnp.array(0.0), None), it, ckpt, 2)
    assert len(out) == 3                     # (params, opt_state, results)


# --------------------------------------------- quarantine + fallback restore
def test_restore_latest_falls_back_over_corrupt_checkpoint(tmp_path):
    d = str(tmp_path)
    params = {"w": jnp.arange(4.0)}
    save_checkpoint(d, 2, params, {"step": jnp.array(2)})
    save_checkpoint(d, 4, params, {"step": jnp.array(4)})
    assert corrupt_latest_checkpoint(d) == 4
    ckpt = Checkpointer(d)
    restored = ckpt.restore_latest(params, {"step": jnp.array(0)})
    assert restored["step"] == 2             # fell back past the bad one
    assert [s for s, _ in ckpt.quarantined] == [4]
    assert os.path.isdir(os.path.join(d, "corrupt_step_00000004"))
    assert not os.path.isdir(os.path.join(d, "step_00000004"))


def test_restore_latest_raises_only_when_all_corrupt(tmp_path):
    d = str(tmp_path)
    params = {"w": jnp.arange(4.0)}
    save_checkpoint(d, 1, params)
    corrupt_latest_checkpoint(d)
    ckpt = Checkpointer(d)
    with pytest.raises(IOError, match="no restorable checkpoint"):
        ckpt.restore_latest(params, None)
    # the bad candidate was quarantined, so a retry sees an empty dir
    assert ckpt.restore_latest(params, None) is None


def test_restore_latest_none_when_empty(tmp_path):
    assert Checkpointer(str(tmp_path / "nope")).restore_latest({}) is None


# ------------------------------------------------- Trainer.fit fault matrix
def _spec(tmp_path, name, **kw):
    kw.setdefault("arch", "qwen2.5-0.5b")
    kw.setdefault("reduced", True)
    kw.setdefault("engine", "mesp")
    kw.setdefault("steps", 8)
    kw.setdefault("seq", 32)
    kw.setdefault("batch", 2)
    kw.setdefault("lr", 5e-3)
    kw.setdefault("ckpt_interval", 3)
    kw.setdefault("ckpt_dir", str(tmp_path / name))
    return TrainSpec(**kw)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def test_crash_resumes_exact_token_stream(tmp_path):
    """A mid-run crash must restore + replay the identical token stream:
    final params bit-identical to the fault-free twin."""
    clean = Trainer.from_spec(_spec(tmp_path, "clean")).fit()
    crashed = Trainer.from_spec(
        _spec(tmp_path, "crash", inject_faults="crash@5")).fit()
    assert crashed.fault_counts["step_failures"] == 1
    assert crashed.fault_counts["steps_replayed"] > 0
    for a, b in zip(_leaves(clean.params), _leaves(crashed.params)):
        np.testing.assert_array_equal(a, b)


def test_oom_degrades_to_memsim_valid_spec(tmp_path):
    res = Trainer.from_spec(
        _spec(tmp_path, "oom", inject_faults="oom@3")).fit()
    assert res.history[-1].step == 8
    assert res.fault_counts["oom_events"] == 1
    assert res.degradations == ["halve_batch"]
    assert res.final_spec.batch == 1
    base = predicted_peak_mb(_spec(tmp_path, "oom"))
    peak = predicted_peak_mb(res.final_spec)
    if base is not None and peak is not None:
        assert peak <= base + 1e-6
    # the degraded spec still round-trips the CLI (it is a real TrainSpec)
    res.final_spec.validate()


def test_oom_with_ladder_off_retries_in_place(tmp_path):
    res = Trainer.from_spec(
        _spec(tmp_path, "noladder", inject_faults="oom@3",
              degrade="off")).fit()
    assert res.degradations == []
    assert res.fault_counts["oom_events"] == 1
    assert res.history[-1].step == 8


def test_nan_loss_skipped_and_run_converges(tmp_path):
    clean = Trainer.from_spec(_spec(tmp_path, "clean2")).fit()
    res = Trainer.from_spec(
        _spec(tmp_path, "nan", inject_faults="nan@4")).fit()
    assert res.fault_counts["guard_skips"] == 1
    assert np.isfinite(res.final_loss)
    assert all(np.isfinite(r.loss) for r in res.history)
    assert abs(res.final_loss - clean.final_loss) < 0.5


def test_corrupt_checkpoint_falls_back_through_fit(tmp_path):
    res = Trainer.from_spec(
        _spec(tmp_path, "corrupt",
              inject_faults="corrupt@4,crash@5")).fit()
    assert res.history[-1].step == 8
    assert res.fault_counts["ckpt_quarantines"] >= 1
    assert res.fault_counts["injected"] == {"corrupt": 1, "crash": 1}


@pytest.mark.parametrize("engine", ["mesp", "mesp_pallas", "mezo"])
def test_crash_matrix_across_engines(tmp_path, engine):
    kw = {"engine": engine}
    if engine == "mezo":
        kw["lr"] = 1e-3
    res = Trainer.from_spec(
        _spec(tmp_path, f"mx_{engine}", steps=6,
              inject_faults="crash@4", **kw)).fit()
    assert res.history[-1].step == 6
    assert res.fault_counts["injected"] == {"crash": 1}
    assert np.isfinite(res.final_loss)


def test_five_fault_chaos_run_completes(tmp_path):
    """The acceptance chaos plan: faults at 5 distinct steps, one of every
    kind, through Trainer.fit — all steps complete, the run ends on a
    memsim-valid spec, and the final loss lands near the fault-free twin."""
    plan = "oom@2,corrupt@4,crash@5,nan@8,stall@10:0.6"
    spec = _spec(tmp_path, "chaos", steps=12, inject_faults=plan,
                 straggler_factor=8.0, straggler_limit=1)
    clean = Trainer.from_spec(_spec(tmp_path, "chaos_clean", steps=12)).fit()
    res = Trainer.from_spec(spec).fit()
    assert res.history[-1].step == 12
    assert res.fault_counts["injected"] == {
        "oom": 1, "corrupt": 1, "crash": 1, "nan": 1, "stall": 1}
    assert res.fault_counts["straggler_restarts"] == 1
    assert res.fault_counts["ckpt_quarantines"] >= 1
    assert res.degradations == ["halve_batch"]
    peak = predicted_peak_mb(res.final_spec)
    if peak is not None:
        base = predicted_peak_mb(spec)
        assert base is None or peak <= base + 1e-6
    assert abs(res.final_loss - clean.final_loss) < 0.5
    # counters all surfaced in the result
    for key in ("step_failures", "oom_events", "degradations", "guard_skips",
                "straggler_restarts", "ckpt_quarantines", "steps_replayed",
                "backoff_seconds", "injected"):
        assert key in res.fault_counts


def test_chaos_cli_round_trip(tmp_path):
    spec = _spec(tmp_path, "cli", inject_faults="oom@4,nan@7",
                 straggler_limit=1, guard_budget=4)
    assert TrainSpec.from_cli_args(spec.to_cli_args()) == spec
