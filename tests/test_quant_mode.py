"""Quantized frozen base weights (``--quantize int8|int4|nf4``) end-to-end.

Three layers of guarantees:

1. **Format**: int8 symmetric per-output-channel round-trip error is bounded
   by half a quantization step per channel; the packed 4-bit formats
   round-trip through the nibble packer at every K parity (the ragged
   odd-K boundary pads with the format's zero nibble), survive all-zero
   columns (scale guard), and the nf4 codebook is strictly monotone;
   ``quantize_frozen`` rewrites exactly the frozen ``w`` leaves and nothing
   else, for every method.
2. **Equivalence**: with the *same* quantized weights, the pallas kernel
   path (int8 dequant / int4-nf4 nibble-unpack in VMEM), the structured jnp
   path (dequantized dense W0) and plain autodiff over the explicitly
   dequantized model all produce the same loss and gradients (≤1e-5
   relative) on non-tile-aligned shapes — the quantized analogue of
   test_pallas_mode's contract.
3. **Lifecycle**: on the kernel path no dense (float) W0-shaped array is
   ever produced outside the Pallas kernels — the dequant-in-VMEM claim,
   checked on the jaxpr for int8 and both packed formats.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import mesp, quant
from repro.kernels import ops, ref
from repro.models import model as M

# Same deliberately non-tile-aligned shape family as test_pallas_mode: none
# of d_model 160 / d_ff 192 / vocab 97 / seq 96 is a multiple of the 128
# block size. f32 so 1e-5 is meaningful.
CFG = ArchConfig(name="quant-test", family="dense", n_layers=2, d_model=160,
                 n_heads=4, n_kv_heads=2, d_ff=192, vocab=97,
                 qkv_bias=True, dtype="float32")


def _batch(seq=96, batch=2):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                                CFG.vocab)
    return {"tokens": tokens, "labels": tokens}


def _flat(tree):
    return jnp.concatenate([t.reshape(-1).astype(jnp.float32)
                            for t in jax.tree_util.tree_leaves(tree)])


def _rel(a, b):
    fa, fb = _flat(a), _flat(b)
    return float(jnp.linalg.norm(fa - fb) /
                 jnp.maximum(jnp.linalg.norm(fb), 1e-30))


@pytest.fixture(scope="module")
def qparams():
    return M.init_params(jax.random.PRNGKey(0), CFG, quantize="int8")


# --------------------------------------------------------------- format


def test_roundtrip_error_bound():
    """|w − dq(q,s)| ≤ s/2 per output channel (round-to-nearest, no
    clipping beyond ±127 by construction of s = amax/127)."""
    w = jax.random.normal(jax.random.PRNGKey(3), (96, 130)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (1, 130)))
    q, s = quant.quantize_int8(w)
    wd = quant.dequantize_int8(q, s, jnp.float32)
    err = jnp.abs(wd - w)
    assert bool(jnp.all(err <= 0.5 * s + 1e-7))
    # the bound is tight-ish: worst channel error above a quarter step
    assert float(jnp.max(err / s)) > 0.25


def test_roundtrip_exact_at_grid_points():
    """Values already on the int8 grid survive the round trip exactly."""
    s = jnp.array([[0.03]], jnp.float32)
    w = (jnp.arange(-127, 128, dtype=jnp.float32)[:, None] * s)
    q, s2 = quant.quantize_int8(w)
    np.testing.assert_allclose(quant.dequantize_int8(q, s2, jnp.float32), w,
                               rtol=0, atol=1e-7)


def test_quantize_frozen_rewrites_only_w(qparams):
    dense = M.init_params(jax.random.PRNGKey(0), CFG)
    attn = qparams["blocks"]["attn"]["q"]
    assert quant.is_quantized(attn["w"]) and attn["w"]["q"].dtype == jnp.int8
    assert attn["a"].dtype == jnp.float32        # LoRA factors untouched
    assert attn["bias"].dtype == jnp.float32     # bias untouched
    assert qparams["embed"]["tok"].dtype == jnp.float32  # embeddings too
    # trainable set identical to the dense tree's
    tm_q = M.trainable_mask(qparams)
    n_train = sum(bool(m) for m in jax.tree_util.tree_leaves(tm_q))
    tm_d = M.trainable_mask(dense)
    assert n_train == sum(bool(m) for m in jax.tree_util.tree_leaves(tm_d))


# ------------------------------------------------------- packed 4-bit fmt


@pytest.mark.parametrize("k", [1, 2, 7, 96, 97])
def test_pack_unpack_roundtrip_all_parities(k):
    """pack→unpack is the identity for every K parity; the ragged odd-K
    boundary stores the pad nibble without disturbing real rows."""
    nib = jax.random.randint(jax.random.PRNGKey(k), (k, 13), 0, 16,
                             dtype=jnp.int32).astype(jnp.uint8)
    packed = quant.pack_nibbles(nib, pad_value=quant.NF4_ZERO_NIBBLE)
    assert packed.shape == ((k + 1) // 2, 13) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(quant.unpack_nibbles(packed, k), nib)
    if k % 2:  # the pad nibble is exactly the requested value
        np.testing.assert_array_equal(
            quant.unpack_nibbles(packed)[-1], quant.NF4_ZERO_NIBBLE)


@pytest.mark.parametrize("method", ["int4", "nf4"])
@pytest.mark.parametrize("k", [97, 96])
def test_packed_roundtrip_error_bound(method, k):
    """Quantize→dequantize error per output channel is bounded by half the
    format's coarsest step (int4: s; nf4: the widest codebook gap × s)."""
    w = jax.random.normal(jax.random.PRNGKey(5), (k, 130)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(6), (1, 130)))
    leaf = quant.quantize_leaf(w, method)
    assert leaf["q4"].shape == ((k + 1) // 2, 130)
    assert ("kpad" in leaf) == bool(k % 2)
    assert quant.packed_k(leaf) == k
    wd = quant.dequantize_packed(leaf["q4"], leaf["scale"], method,
                                 jnp.float32, k=k)
    if method == "int4":
        step = leaf["scale"]          # grid spacing = scale (q ∈ [-7, 7])
    else:
        code = jnp.asarray(quant.NF4_CODE)
        step = float(jnp.max(jnp.diff(code))) * leaf["scale"]
    assert bool(jnp.all(jnp.abs(wd - w) <= 0.5 * step + 1e-6))


@pytest.mark.parametrize("method", ["int4", "nf4"])
def test_packed_all_zero_columns(method):
    """All-zero output channels must not divide by zero: scale is guarded
    and the round trip returns exact zeros (no NaN/Inf)."""
    w = jax.random.normal(jax.random.PRNGKey(7), (33, 6)) * 0.1
    w = w.at[:, ::2].set(0.0)
    leaf = quant.quantize_leaf(w, method)
    wd = quant.dequantize_packed(leaf["q4"], leaf["scale"], method,
                                 jnp.float32, k=33)
    assert bool(jnp.all(jnp.isfinite(wd)))
    np.testing.assert_array_equal(wd[:, ::2], 0.0)


def test_nf4_codebook_monotone_with_exact_zero():
    code = np.asarray(quant.NF4_CODE)
    assert code.shape == (16,)
    assert bool(np.all(np.diff(code) > 0))          # strictly increasing
    assert code[quant.NF4_ZERO_NIBBLE] == 0.0       # pad nibble is exact 0
    assert code[0] == -1.0 and code[-1] == 1.0


def test_nf4_quantize_picks_nearest_code():
    """searchsorted-on-midpoints must equal the brute-force nearest code."""
    w = jax.random.normal(jax.random.PRNGKey(8), (40, 9))
    leaf = quant.quantize_leaf(w, "nf4")
    nib = quant.unpack_nibbles(leaf["q4"], 40)
    code = jnp.asarray(quant.NF4_CODE)
    brute = jnp.argmin(
        jnp.abs(w[..., None] / leaf["scale"][..., None] - code), axis=-1)
    np.testing.assert_array_equal(nib, brute.astype(nib.dtype))


@pytest.mark.parametrize("method", ["int4", "nf4"])
def test_quantize_frozen_packed_rewrites_only_w(method):
    qp = M.init_params(jax.random.PRNGKey(0), CFG, quantize=method)
    attn = qp["blocks"]["attn"]["q"]
    assert quant.is_packed(attn["w"])
    assert attn["w"]["q4"].dtype == jnp.uint8
    assert quant.packed_method(attn["w"]) == method
    assert attn["a"].dtype == jnp.float32
    assert qp["embed"]["tok"].dtype == jnp.float32
    # stacked block leaves keep a uniform leading axis (scan contract)
    lead = {v.shape[0] for v in jax.tree_util.tree_leaves(qp["blocks"])}
    assert lead == {CFG.n_layers}


def test_requantize_int8_to_int4_transition():
    """The degradation ladder's int8→int4 rung is a plain re-call: already
    quantized leaves are dequantized and re-packed, not double-quantized."""
    w = jax.random.normal(jax.random.PRNGKey(9), (96, 130)) * 0.1
    tree = {"w": dict(quant.quantize_leaf(w, "int8")), "a": w[:, :4]}
    tree4 = quant.quantize_params({"x": tree}, "int4")["x"]
    assert quant.is_packed(tree4["w"])
    w8 = quant.maybe_dequant(tree["w"], jnp.float32)
    w4 = quant.maybe_dequant(tree4["w"], jnp.float32)
    # error vs the int8 stage it was re-packed from, not vs the original
    assert float(jnp.max(jnp.abs(w4 - w8))) <= \
        float(jnp.max(tree4["w"]["scale"])) * 0.5 + 1e-6
    np.testing.assert_array_equal(tree4["a"], tree["a"])  # LoRA untouched


# ----------------------------------------------------------- equivalence


@pytest.mark.parametrize("seq", [96, 48])
def test_quant_pallas_grads_match_quant_structured(qparams, seq):
    """Quantized-pallas vs quantized-structured ≤1e-5 relative; seq 96
    exercises the flash kernel, seq 48 the attention fallback."""
    batch = _batch(seq=seq)
    l_s, g_s = mesp.value_and_grad(qparams, CFG, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(qparams, CFG, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-6)
    assert _rel(g_p, g_s) <= 1e-5


def test_quant_pallas_grads_match_dequant_oracle(qparams):
    """The unquantized-dequant oracle: plain autodiff over a dense model
    whose weights are the explicitly dequantized q·s."""
    dense = jax.tree_util.tree_map(
        lambda p: quant.maybe_dequant(p, jnp.float32) if quant.is_quantized(p)
        else p, qparams, is_leaf=quant.is_quantized)
    batch = _batch()
    _, g_oracle = mesp.value_and_grad(dense, CFG, batch, mode="plain")
    _, g_p = mesp.value_and_grad(qparams, CFG, batch, mode="pallas")
    assert _rel(g_p, g_oracle) <= 1e-5


def test_quant_train_step_descends_and_matches(qparams):
    batch = _batch()
    p_s, _ = mesp.train_step(qparams, CFG, batch, 1e-2, mode="structured")
    p_p, l0 = mesp.train_step(qparams, CFG, batch, 1e-2, mode="pallas")
    for a, b in zip(jax.tree_util.tree_leaves(p_p),
                    jax.tree_util.tree_leaves(p_s)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)
    p = p_p
    for _ in range(3):
        p, l = mesp.train_step(p, CFG, batch, 5e-2, mode="pallas")
    assert float(l) < float(l0)


def test_quant_kernel_matches_ref_oracle():
    """ops-level: quantized kernel vs the jnp oracle on the dequantized W0."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (192, 160)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (160, 200)) * 0.05
    a = jax.random.normal(jax.random.PRNGKey(2), (160, 8)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(3), (8, 200)) * 0.3
    q, s = quant.quantize_int8(w)
    wd = quant.dequantize_int8(q, s, jnp.float32)
    y = ops.lora_linear(x, {"q": q, "scale": s}, a, b, None, 2.0)
    np.testing.assert_allclose(y, ref.lora_fused_ref(x, wd, a, b, 2.0),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("method", ["int4", "nf4"])
def test_packed_pallas_grads_match_structured_and_oracle(method):
    """Packed-pallas ≡ packed-structured ≡ dequant-oracle (≤1e-5 relative)
    on the non-tile-aligned model — the packed analogue of the int8
    contract above, in one pass per method."""
    qp = M.init_params(jax.random.PRNGKey(0), CFG, quantize=method)
    batch = _batch()
    l_s, g_s = mesp.value_and_grad(qp, CFG, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(qp, CFG, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-6)
    assert _rel(g_p, g_s) <= 1e-5
    dense = jax.tree_util.tree_map(
        lambda p: quant.maybe_dequant(p, jnp.float32),
        qp, is_leaf=quant.is_packed)
    _, g_oracle = mesp.value_and_grad(dense, CFG, batch, mode="plain")
    assert _rel(g_p, g_oracle) <= 1e-5


@pytest.mark.parametrize("method", ["int4", "nf4"])
def test_packed_kernel_matches_ref_oracle_odd_k(method):
    """ops-level on a ragged odd-K shape: the packed kernel vs the jnp
    oracle over the explicitly dequantized W0."""
    K, N, r = 97, 131, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (50, K)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.05
    a = jax.random.normal(jax.random.PRNGKey(2), (K, r)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(3), (r, N)) * 0.3
    leaf = quant.quantize_leaf(w, method)
    wd = quant.maybe_dequant(leaf, jnp.float32)
    y = ops.lora_linear(x, leaf, a, b, None, 2.0)
    np.testing.assert_allclose(y, ref.lora_fused_ref(x, wd, a, b, 2.0),
                               rtol=2e-5, atol=2e-5)


def test_quant_dispatch_falls_back_on_moe_shapes():
    """Per-expert [E,·,·] quantized weights take the structured dequant
    path through the dispatcher, with correct LoRA gradients."""
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    E, C, d, f, r = 2, 8, 16, 12, 4
    x = jax.random.normal(keys[0], (E, C, d))
    w0 = jax.random.normal(keys[1], (E, d, f)) * 0.1
    a = jax.random.normal(keys[2], (E, d, r)) * 0.3
    b = jax.random.normal(keys[3], (E, r, f)) * 0.3
    q, s = quant.quantize_int8(w0)
    wl = {"q": q, "scale": s}
    wd = quant.dequantize_int8(q, s, jnp.float32)
    assert not ops.lora_supported(x, wl)
    f1 = lambda x, a, b: jnp.sum(jnp.tanh(ops.lora_linear(x, wl, a, b,
                                                          None, 2.0)))
    f2 = lambda x, a, b: jnp.sum(jnp.tanh(x @ wd + 2.0 * ((x @ a) @ b)))
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- lifecycle


def _sub_jaxprs(eqn):
    from jax.core import ClosedJaxpr, Jaxpr
    vals = []
    for v in eqn.params.values():
        vals += v if isinstance(v, (list, tuple)) else [v]
    for v in vals:
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v


def _float_w0_shapes(jaxpr, forbidden):
    """Float arrays of a dense-W0 shape produced OUTSIDE pallas kernels."""
    hits = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue  # inside the kernel IS VMEM — that's the design
        for sub in _sub_jaxprs(eqn):
            hits += _float_w0_shapes(sub, forbidden)
        for v in eqn.outvars:
            aval = v.aval
            if getattr(aval, "shape", None) in forbidden and \
                    jnp.issubdtype(aval.dtype, jnp.floating):
                hits.append((eqn.primitive.name, aval.shape, aval.dtype))
    return hits


def test_no_dense_w0_materialized_on_kernel_path():
    """fwd+bwd of the quantized kernel op never produce a float [K,N]/[N,K]
    array outside pallas_call — W0 exists only in VMEM. (Any jnp dequant
    happens before padding, so the exact shape is the discriminating one;
    padded shapes collide with padded activations.)"""
    K, N, r = 160, 200, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (192, K)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.05
    a = jax.random.normal(jax.random.PRNGKey(2), (K, r)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(3), (r, N)) * 0.3
    q, s = quant.quantize_int8(w)

    def loss(x, a, b):
        y = ops.lora_linear(x, {"q": q, "scale": s}, a, b, None, 2.0)
        return jnp.sum(y * y)

    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1, 2)))(x, a, b)
    hits = _float_w0_shapes(jaxpr.jaxpr, {(K, N), (N, K)})
    assert not hits, f"dense W0 materialized outside kernels: {hits}"


@pytest.mark.parametrize("method", ["int4", "nf4"])
def test_no_dense_w0_materialized_on_packed_kernel_path(method):
    """PR-2 invariant extended to the packed formats: fwd+bwd of the packed
    op never produce a float [K,N]/[N,K] array outside pallas_call — the
    nibble unpack happens on the VPU, in VMEM."""
    K, N, r = 160, 200, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (192, K)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.05
    a = jax.random.normal(jax.random.PRNGKey(2), (K, r)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(3), (r, N)) * 0.3
    leaf = quant.quantize_leaf(w, method)

    def loss(x, a, b):
        y = ops.lora_linear(x, leaf, a, b, None, 2.0)
        return jnp.sum(y * y)

    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1, 2)))(x, a, b)
    hits = _float_w0_shapes(jaxpr.jaxpr, {(K, N), (N, K)})
    assert not hits, f"dense W0 materialized outside kernels: {hits}"


def test_structured_fallback_does_materialize_w0():
    """Sanity for the guard above: the structured dequant path *does*
    materialize the dense W0 (so the check is actually discriminating)."""
    K, N, r = 160, 200, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (192, K)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.05
    a = jax.random.normal(jax.random.PRNGKey(2), (K, r)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(3), (r, N)) * 0.3
    q, s = quant.quantize_int8(w)
    from repro.core import structured

    def loss(x, a, b):
        y = structured.lora_linear(x, quant.maybe_dequant({"q": q, "scale": s},
                                                          x.dtype),
                                   a, b, None, 2.0)
        return jnp.sum(y * y)

    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1, 2)))(x, a, b)
    hits = _float_w0_shapes(jaxpr.jaxpr, {(K, N)})
    assert hits
