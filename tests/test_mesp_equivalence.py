"""Paper §5.5 equivalence claims at system level:

* MeSP gradients ≡ MeBP gradients (identical losses, allclose grads)
* store-h ablation ≡ recompute-h (Table 5: same math, different memory)
* sequential (immediate-update) engine ≡ production (accumulate) engine
* MeSP/MeBP produce identical loss trajectories under the same seed (Fig 2)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import mebp, mesp
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-0.5b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    return cfg, params, batch


def _flat(tree):
    return jax.tree_util.tree_leaves(tree)


def test_mesp_equals_mebp_gradients(setup):
    cfg, params, batch = setup
    l1, g1 = mesp.value_and_grad(params, cfg, batch)
    l2, g2 = mebp.value_and_grad(params, cfg, batch)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    for u, v in zip(_flat(g1), _flat(g2)):
        np.testing.assert_allclose(u, v, rtol=5e-5, atol=5e-5)


def test_storeh_equals_recompute(setup):
    cfg, params, batch = setup
    _, g1 = mesp.value_and_grad(params, cfg, batch, mode="structured")
    _, g2 = mesp.value_and_grad(params, cfg, batch, mode="store_h")
    for u, v in zip(_flat(g1), _flat(g2)):
        np.testing.assert_allclose(u, v, rtol=1e-6, atol=1e-6)


def test_sequential_equals_production_sgd(setup):
    cfg, params, batch = setup
    p1, l1 = mesp.train_step(params, cfg, batch, 0.05)
    p2, l2 = mesp.sequential_train_step(params, cfg, batch, 0.05)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    for u, v in zip(_flat(p1), _flat(p2)):
        np.testing.assert_allclose(u, v, rtol=1e-5, atol=1e-6)


def test_identical_loss_trajectories(setup):
    """Fig 2 / Table 11: MeBP and MeSP loss values match step-for-step."""
    cfg, params, batch = setup
    p_a = p_b = params
    for _ in range(3):
        p_a, l_a = mesp.train_step(p_a, cfg, batch, 0.05)
        p_b, l_b = mebp.train_step(p_b, cfg, batch, 0.05)
        np.testing.assert_allclose(l_a, l_b, rtol=1e-5)


def test_only_lora_params_update(setup):
    cfg, params, batch = setup
    p1, _ = mesp.train_step(params, cfg, batch, 0.05)
    mask = M.trainable_mask(params)
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), params, p1)
    flat_mask = _flat(mask)
    flat_changed = _flat(changed)
    for m, c in zip(flat_mask, flat_changed):
        if not m:
            assert not c, "frozen parameter changed"
    assert any(c for m, c in zip(flat_mask, flat_changed) if m), \
        "no LoRA parameter changed"
