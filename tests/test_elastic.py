"""Elastic resize tests: a mid-run reshard (``reshard_tree``) or a
checkpoint-restore onto a different mesh must not perturb the optimizer
trajectory — bit-identical params/opt-state vs an uninterrupted run —
and ``rebalance_batch`` keeps the global batch invariant over host counts.
(The module docstring of ``runtime/elastic.py`` promises exactly this.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer
from repro.data import make_batch_iterator
from repro.optim import make_optimizer
from repro.runtime.elastic import (make_mesh_from_devices, rebalance_batch,
                                   reshard_tree)


# --------------------------------------------------------- rebalance_batch
def test_rebalance_batch_keeps_global_invariant():
    # shrink 16 -> 8 hosts: per-host batch doubles, global stays 256
    assert rebalance_batch(256, 16, 8) == 32
    assert rebalance_batch(256, 16, 8) * 8 == rebalance_batch(
        256, 16, 16) * 16 == 256
    # grow 4 -> 8 hosts: per-host batch halves
    assert rebalance_batch(64, 4, 8) == 8


def test_rebalance_batch_rejects_non_divisor_host_count():
    # ValueError, not AssertionError: the guard must survive ``python -O``
    with pytest.raises(ValueError, match="cannot be kept invariant"):
        rebalance_batch(256, 16, 7)
    with pytest.raises(ValueError, match="cannot be kept invariant"):
        rebalance_batch(256, 16, 0)


def test_rebalance_batch_shrink_chain_preserves_global():
    # 8 -> 6 -> 4 hosts (a straggler drain): per-host batch grows at every
    # step and the global product is invariant throughout
    global_batch, chain = 24, [8, 6, 4]
    for old, new in zip(chain, chain[1:]):
        per_host = rebalance_batch(global_batch, old, new)
        assert per_host * new == global_batch
    assert [rebalance_batch(global_batch, 8, n) for n in chain] == [3, 4, 6]


# ------------------------------------------------- make_mesh_from_devices
def test_make_mesh_rejects_non_divisible_survivors():
    devs = jax.devices()
    with pytest.raises(ValueError, match="not divisible"):
        make_mesh_from_devices(devs, model_parallel=len(devs) + 1)
    with pytest.raises(ValueError, match="not divisible"):
        make_mesh_from_devices([], model_parallel=1)


def test_make_mesh_rejects_bad_axis_sizes():
    devs = jax.devices()
    with pytest.raises(ValueError, match="must be >= 1"):
        make_mesh_from_devices(devs, model_parallel=0)
    with pytest.raises(ValueError, match="must be >= 1"):
        make_mesh_from_devices(devs, model_parallel=1, pods=0)


def test_make_mesh_single_pod_axis_naming():
    mesh = make_mesh_from_devices(jax.devices(), model_parallel=1)
    # single pod: no "pod" axis — launch/sharding.py's dp_axes contract
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1


# ------------------------------------------------------------ reshard_tree
def _mesh():
    return make_mesh_from_devices(jax.devices(), model_parallel=1)


def test_reshard_tree_is_placement_only():
    mesh = _mesh()
    tree = {"a": jnp.arange(8.0), "b": jnp.ones((4, 2)), "frozen": None}
    specs = {"a": P(), "b": P(), "frozen": None}
    out = reshard_tree(tree, mesh, specs)
    assert out["frozen"] is None
    for k in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))
        assert out[k].sharding == NamedSharding(mesh, specs[k])


# --------------------------------------- trajectory invariance over resizes
#: a tiny LoRA-shaped problem: frozen "w" (grad None, like the engines
#: emit), trainable "a"/"b" — enough structure to exercise momentum state
def _problem():
    params = {"w": jnp.ones((4, 4)),
              "a": jax.random.normal(jax.random.PRNGKey(0), (4, 2)) * 0.1,
              "b": jnp.zeros((2, 4))}
    specs = {"w": P(), "a": P(), "b": P()}

    def grads(params, batch):
        x = batch["tokens"][:, :4].astype(jnp.float32)
        y = batch["labels"][:, :4].astype(jnp.float32)

        def loss(a, b):
            return jnp.mean((x @ params["w"] @ a @ b - y) ** 2)

        ga, gb = jax.grad(loss, argnums=(0, 1))(params["a"], params["b"])
        return {"w": None, "a": ga, "b": gb}   # frozen slot: sparse grads

    return params, specs, grads


def _run(opt, params, grads, batches, reshard_at=None, mesh=None,
         specs=None, state=None):
    state = state if state is not None else opt.init(params)
    for i, batch in enumerate(batches):
        if reshard_at is not None and i == reshard_at:
            # elastic resize mid-run: placement changes, values must not
            params = reshard_tree(params, mesh, specs)
            state = {k: (reshard_tree(v, mesh, specs)
                         if isinstance(v, dict) else v)
                     for k, v in state.items()}
        params, state = opt.update(grads(params, batch), state, params)
    return params, state


@pytest.mark.parametrize("optimizer", ["sgd", "sgd_momentum", "adamw"])
def test_midrun_reshard_keeps_trajectory_bit_identical(optimizer):
    from repro.optim.schedules import constant

    params, specs, grads = _problem()
    opt = make_optimizer(optimizer, constant(1e-2))
    it = make_batch_iterator(50, 8, 2, n_tokens=4096)
    batches = [next(it) for _ in range(8)]

    p_ref, s_ref = _run(opt, params, grads, batches)
    p_rs, s_rs = _run(opt, params, grads, batches, reshard_at=4,
                      mesh=_mesh(), specs=specs)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_rs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(s_ref),
                    jax.tree_util.tree_leaves(s_rs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_onto_resized_mesh_keeps_trajectory(tmp_path):
    """Save mid-run, 'come back on a different topology' (restore with
    explicit shardings + rebalanced per-host batch), finish the run —
    bit-identical to the uninterrupted trajectory, including the exact
    token stream (DataState round-trips through the manifest)."""
    from repro.data.pipeline import DataState
    from repro.optim.schedules import constant

    params, specs, grads = _problem()
    opt = make_optimizer("sgd_momentum", constant(1e-2))
    mesh = _mesh()

    def fresh_iter(state=None):
        return make_batch_iterator(50, 8, 4, n_tokens=4096, state=state)

    # uninterrupted 8-step reference
    it = fresh_iter()
    p_ref, s_ref = _run(opt, params, grads, [next(it) for _ in range(8)])

    # interrupted at 4: checkpoint (logical/unsharded layout) ...
    it = fresh_iter()
    p_mid, s_mid = _run(opt, params, grads, [next(it) for _ in range(4)])
    ckpt = Checkpointer(str(tmp_path), interval=1)
    ckpt.save(4, p_mid, s_mid, data_state=it.state.to_dict())

    # ... then restore onto the "resized" mesh with explicit shardings and
    # the rebalanced per-host batch (global batch 4 kept invariant)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs)
    restored = ckpt.restore_latest(
        jax.tree_util.tree_map(jnp.zeros_like, p_mid), s_mid,
        shardings=shardings)
    assert restored["step"] == 4
    local_batch = rebalance_batch(4, 2, 1)
    assert local_batch == 4
    it2 = fresh_iter(state=DataState.from_dict(restored["data_state"]))
    p_fin, s_fin = _run(opt, restored["params"], grads,
                        [next(it2) for _ in range(4)],
                        state=restored["opt_state"])
    # momentum state survives the manifest round trip: trajectory identical
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_fin)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(s_ref),
                    jax.tree_util.tree_leaves(s_fin)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
