"""In-process halves of the emulated-fleet harness: XLA_FLAGS plumbing,
sharding-aware autotune cache keys, the --model-parallel spec field, the
quantized-leaf sharding rules and the degrade-ladder × sharding seam.
(Everything needing real multi-device meshes lives in tests/multihost/.)"""
import json
import os
import warnings

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.api.spec import TrainSpec, build_arg_parser
from repro.kernels import autotune
from repro.launch import sharding as sh
from repro.launch.xla_flags import (force_host_device_count,
                                    jax_initialized)


# ------------------------------------------------------------- xla_flags
def test_force_host_device_count_appends_not_overwrites():
    env = {"XLA_FLAGS": "--xla_dump_to=/tmp/d --xla_foo=1"}
    assert force_host_device_count(8, env=env)
    assert "--xla_dump_to=/tmp/d" in env["XLA_FLAGS"]
    assert "--xla_foo=1" in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]


def test_force_host_device_count_replaces_existing_request():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=512 "
                        "--xla_bar=2"}
    force_host_device_count(4, env=env)
    assert env["XLA_FLAGS"].count("xla_force_host_platform_device_count") == 1
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "--xla_bar=2" in env["XLA_FLAGS"]


def test_force_host_device_count_warns_when_too_late(monkeypatch):
    jax.devices()   # force backend init (importing jax alone is not enough)
    assert jax_initialized()
    monkeypatch.setenv("XLA_FLAGS", "")
    with pytest.warns(UserWarning, match="after JAX initialized"):
        ok = force_host_device_count(4)
    assert ok is False
    # the flag is still written: a *subprocess* inheriting the env works
    assert "--xla_force_host_platform_device_count=4" in \
        os.environ["XLA_FLAGS"]


def test_env_copy_never_warns_even_after_init():
    env = dict(os.environ)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert force_host_device_count(8, env=env)


# -------------------------------------------------- autotune: mesh-aware keys
def test_key_format_unchanged_without_mesh():
    # the committed autotune_cache/*.json keys must keep hitting
    k = autotune._key("rmsnorm", {"M": 1024, "d": 64}, "float32")
    assert k == f"rmsnorm|M=1024/d=64|float32|{jax.default_backend()}"
    assert "mesh=" not in k


def test_local_dims_divides_sharded_dims():
    dims = {"M": 1024, "K": 64, "N": 128}
    out = autotune._local_dims(dims, {"data": 4, "model": 2})
    assert out == {"M": 256, "K": 64, "N": 128}
    # non-divisible dims stay global
    assert autotune._local_dims({"M": 10}, {"data": 4, "model": 1}) == \
        {"M": 10}
    # flash seq dims split over the model (Megatron-SP) axis
    out = autotune._local_dims({"Nq": 512, "Nk": 512, "D": 64},
                               {"data": 2, "model": 2})
    assert out == {"Nq": 256, "Nk": 256, "D": 64}
    # pods compose into the DP factor
    assert autotune._local_dims({"M": 64}, {"pod": 2, "data": 2,
                                            "model": 1}) == {"M": 16}


def test_no_ambient_mesh_in_this_process():
    # the unit-test process never enters a mesh context: ambient lookup is
    # None and keys stay in the historical format
    assert autotune._active_mesh() is None


def test_key_tags_mesh_and_keeps_backend_suffix(fake_mesh):
    mesh = fake_mesh(4, 2)
    k = autotune._key("lora_fused", {"M": 128, "K": 64, "N": 64},
                      "float32", mesh=mesh)
    assert "|mesh=data4xmodel2|" in k
    assert "M=32" in k   # local rows: 128 / dp=4
    # save_cache filters on the backend suffix — sharded entries must keep it
    assert k.endswith("|" + jax.default_backend())


def test_save_cache_keeps_sharded_entries(tmp_path, fake_mesh, monkeypatch):
    mesh = fake_mesh(2, 1)
    autotune._ensure_loaded()
    k_plain = autotune._key("rmsnorm", {"M": 64, "d": 32}, "float32")
    k_mesh = autotune._key("rmsnorm", {"M": 64, "d": 32}, "float32",
                           mesh=mesh)
    assert k_plain != k_mesh
    autotune._CACHE[k_plain] = {"bm": 128}
    autotune._CACHE[k_mesh] = {"bm": 256}
    try:
        path = autotune.save_cache(str(tmp_path / "cpu.json"))
        saved = json.load(open(path))
        assert saved[k_plain] == {"bm": 128}
        assert saved[k_mesh] == {"bm": 256}
        # the two contexts resolve to different winners
        monkeypatch.setattr(autotune, "_active_mesh", lambda: mesh)
        assert autotune.choose_blocks("rmsnorm", "float32",
                                      M=64, d=32) == {"bm": 256}
        monkeypatch.setattr(autotune, "_active_mesh", lambda: None)
        assert autotune.choose_blocks("rmsnorm", "float32",
                                      M=64, d=32) == {"bm": 128}
    finally:
        autotune._CACHE.pop(k_plain, None)
        autotune._CACHE.pop(k_mesh, None)


# --------------------------------------------------- spec: --model-parallel
def test_model_parallel_cli_round_trip():
    spec = TrainSpec(model_parallel=4)
    argv = spec.to_cli_args()
    assert "--model-parallel" in argv
    assert TrainSpec.from_cli_args(argv) == spec
    ns = build_arg_parser().parse_args([])
    assert ns.model_parallel == 1


def test_model_parallel_must_be_positive():
    with pytest.raises(ValueError, match="model-parallel"):
        TrainSpec(model_parallel=0).validate()


# ------------------------------------------- quantized-leaf sharding rules
def test_quantized_leaves_follow_weight_layout(fake_mesh):
    from repro.configs import get_config
    from repro.core.quant import quantize_params
    from repro.models import model as model_lib

    cfg = get_config("qwen2.5-0.5b").reduced()
    mesh = fake_mesh(2, 2)
    params = jax.eval_shape(
        lambda: quantize_params(model_lib.init_params(
            jax.random.PRNGKey(0), cfg), "int8"))
    specs = sh.param_specs(cfg, params, mesh)
    qkv = specs["blocks"]["attn"]["q"]["w"]
    # column-parallel projection: int8 q sharded like w, scale [1, d_out]
    # follows the out dim
    assert tuple(qkv["q"]) == (None, None, "model")
    assert tuple(qkv["scale"]) == (None, None, "model")
    down = specs["blocks"]["mlp"]["down"]["w"]
    # row-parallel: q sharded on d_in; scale's size-1 dim guarded off
    assert tuple(down["q"]) == (None, "model", None)
    assert tuple(down["scale"]) == (None, None, None)


# ------------------------------------------- degrade ladder × sharding seam
def test_ladder_rungs_produce_mesh_coherent_specs(fake_mesh):
    """Every registry-valid ladder rung must yield a spec the sharding stack
    can place on a model-parallel mesh: batch_spec falls back to replication
    when the halved batch stops dividing DP, and activation_spec only puts
    seq on the model axis when it still divides."""
    from repro.runtime.degrade import DegradationLadder

    mesh = fake_mesh(2, 2)
    base = TrainSpec(reduced=True, engine="mesp_pallas", optimizer="sgd",
                     batch=2, seq=64, model_parallel=2)
    rungs = list(DegradationLadder().candidates(base))
    assert {r for _, r in rungs} >= {"halve_batch", "engine_mesp",
                                     "quantize_int8", "truncate_seq"}
    for cand, rung in rungs:
        cand.validate()
        bspec = sh.batch_spec(mesh, cand.batch)    # must never raise
        if cand.batch % 2:   # dp=2 no longer divides: replicate
            assert tuple(bspec) == ()
        msize = 2
        act = sh.activation_spec(mesh, cand.batch,
                                 seq_on_model=(cand.seq % msize == 0))
        assert all(ax in (None, "data", "model") or
                   all(a in ("data", "model") for a in ax)
                   for ax in tuple(act)), (rung, act)
