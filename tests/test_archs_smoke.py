"""Per-architecture smoke tests: REDUCED same-family configs run one
forward/train step + decode steps on CPU, asserting shapes and finiteness.
(The FULL configs are exercised via the dry-run only.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY, SHAPES, get_config, \
    shape_applicable
from repro.core import mesp
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, N=16):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["frontend_embeds"] = jnp.full(
            (B, cfg.frontend_tokens, cfg.d_model), 0.01, jnp.float32)
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.full(
            (B, cfg.encdec.encoder_seq, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    params2, loss = mesp.train_step(params, cfg, batch, 1e-2)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    for leaf in jax.tree_util.tree_leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN in params"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_output_shape(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits = M.forward(params, cfg, batch["tokens"],
                       frontend_embeds=batch.get("frontend_embeds"),
                       enc_frames=batch.get("enc_frames"))
    n_expected = batch["tokens"].shape[1] + (
        cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, n_expected, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_steps(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(KEY, cfg)
    B = 2
    cache = M.init_cache(cfg, B, 32)
    if cfg.family == "audio":
        cache["enc_out"] = jnp.full(
            (B, cfg.encdec.encoder_seq, cfg.d_model), 0.01, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = M.decode_step(params, cfg, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits[:, :, :64], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_configs_match_assignment(arch):
    """Full (non-reduced) config fields match the assignment table."""
    cfg = REGISTRY[arch]
    expected = {
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_moe_configs():
    o = REGISTRY["olmoe-1b-7b"].moe
    assert (o.n_experts, o.top_k, o.n_shared) == (64, 8, 0)
    d = REGISTRY["deepseek-moe-16b"].moe
    assert (d.n_experts, d.top_k, d.n_shared) == (64, 6, 2)
    assert d.first_layer_dense


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runs = [a for a in ASSIGNED if shape_applicable(REGISTRY[a], long)[0]]
    assert set(runs) == {"gemma3-12b", "rwkv6-1.6b", "recurrentgemma-2b"}
