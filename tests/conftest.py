import os

# Tests run on the single real CPU device; only the dry-run uses 512
# placeholder devices (set inside launch/dryrun.py, NOT here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def fake_mesh():
    """Factory for an AbstractMesh — sharding-spec construction tests need
    mesh *geometry* only, and a real Mesh can't be built from one CPU device
    (the emulated-fleet suite in tests/multihost/ covers real meshes)."""
    def make(data=4, model=4):
        # JAX 0.4.x wants ((name, size), ...); 0.5+ wants (sizes, names).
        try:
            return jax.sharding.AbstractMesh((("data", data),
                                              ("model", model)))
        except TypeError:
            return jax.sharding.AbstractMesh((data, model),
                                             ("data", "model"))
    return make
