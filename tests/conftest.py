import os

# Tests run on the single real CPU device; only the dry-run uses 512
# placeholder devices (set inside launch/dryrun.py, NOT here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
