"""End-to-end behaviour: a small MeSP fine-tune actually reduces loss, the
three methods rank as the paper reports, and serve-after-train works.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import mebp, mesp, mezo
from repro.data import make_batch_iterator
from repro.models import model as M


def _setup(seq=32, batch=4):
    cfg = get_config("qwen2.5-0.5b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    it = make_batch_iterator(cfg.vocab, seq, batch, n_tokens=1 << 15, seed=1)
    return cfg, params, it


def test_mesp_training_reduces_loss():
    cfg, params, it = _setup()
    step = jax.jit(lambda p, b: mesp.train_step(p, cfg, b, 5e-2))
    losses = []
    for _ in range(30):
        params, loss = step(params, next(it))
        losses.append(float(loss))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, (first, last)


def test_mesp_and_mebp_trajectories_identical_mezo_differs():
    """Fig 2: same seed -> MeSP/MeBP identical; MeZO behind."""
    cfg, params, it = _setup()
    batches = [next(it) for _ in range(8)]
    pa = pb = pc = params
    la, lb, lc = [], [], []
    for i, b in enumerate(batches):
        pa, l1 = mesp.train_step(pa, cfg, b, 5e-2)
        pb, l2 = mebp.train_step(pb, cfg, b, 5e-2)
        pc, l3 = mezo.train_step(pc, cfg, b, jax.random.PRNGKey(i), 5e-3)
        la.append(float(l1)), lb.append(float(l2)), lc.append(float(l3))
    np.testing.assert_allclose(la, lb, rtol=1e-4)
    # MeZO's loss decrease over the window is smaller than exact-gradient's
    assert (la[0] - la[-1]) > (lc[0] - lc[-1]) - 1e-3


def test_train_then_decode():
    cfg, params, it = _setup()
    for _ in range(3):
        params, _ = mesp.train_step(params, cfg, next(it), 1e-2)
    cache = M.init_cache(cfg, 2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    for _ in range(4):
        logits, cache = M.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.all(jnp.isfinite(logits)))
