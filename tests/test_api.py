"""repro.api: TrainSpec round-trip, registry completeness, validation
errors, and the registering-an-engine-needs-no-core-edits property."""
import inspect

import jax
import jax.numpy as jnp
import pytest

from repro.api import (ExecutionPolicy, Trainer, TrainSpec,
                       UnknownEngineError, build_arg_parser, engine_names,
                       get_engine, list_engines, register_engine,
                       unregister_engine)


# ---------------------------------------------------------------- TrainSpec


def test_spec_cli_round_trip():
    spec = TrainSpec(arch="qwen2.5-1.5b", reduced=True, engine="mesp_pallas",
                     quantize="int8", optimizer="adamw", lr=3e-3, steps=7,
                     batch=2, seq=32, seed=5, ckpt_dir="/tmp/rt",
                     ckpt_interval=3, log_interval=2, flash_min_seq=256,
                     flash_chunk=128, pallas_interpret=True)
    argv = spec.to_cli_args()
    assert TrainSpec.from_cli_args(argv) == spec


def test_default_spec_round_trips_as_empty_argv():
    assert TrainSpec().to_cli_args() == []
    assert TrainSpec.from_cli_args([]) == TrainSpec()


def test_spec_policy_derivation():
    spec = TrainSpec(engine="mesp_pallas", quantize="int8",
                     pallas_interpret=False, flash_min_seq=512)
    pol = spec.policy()
    assert pol.backend == "pallas" and pol.quantize == "int8"
    assert pol.interpret is False and pol.flash_min_seq == 512
    # engines with a custom regime (mezo) thread the plain backend
    assert TrainSpec(engine="mezo").policy().backend == "plain"


# ----------------------------------------------------------------- registry


def test_parser_engine_choices_come_from_registry():
    (engine_action,) = [a for a in build_arg_parser()._actions
                        if a.dest == "engine"]
    assert tuple(engine_action.choices) == engine_names()


def test_builtin_engines_registered():
    names = set(engine_names())
    assert {"mesp", "mesp_pallas", "mesp_seq", "mebp", "store_h",
            "mezo", "mezo_sparse", "mezo_lowrank", "mezo_block",
            "mezo_avg4"} <= names
    # §4.3 sequential engine is first-class: registered, CLI-selectable
    seq = get_engine("mesp_seq")
    assert seq.backend == "structured" and seq.memsim == "mesp"


def test_zo_engines_complete_across_cli_bench_memsim_readme():
    """Completeness: every registered ZO engine (backend=None + a
    value_and_grad hook, i.e. the repro.zo registrations) is a CLI choice,
    a benchmark-sweep member, memsim-resolvable and a README-matrix row —
    with zero edits to launch/train.py, benchmarks/run.py or models/*."""
    import importlib.util
    from pathlib import Path

    from repro.zo.gradquality import zo_engine_names

    zo = zo_engine_names()
    assert set(zo) >= {"mezo", "mezo_sparse", "mezo_lowrank", "mezo_block",
                       "mezo_avg4"}

    (engine_action,) = [a for a in build_arg_parser()._actions
                        if a.dest == "engine"]
    from benchmarks.run import _engines
    from benchmarks.memsim import RETENTION_MODELS, _retention_model

    root = Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "check_readme_flags", root / "scripts" / "check_readme_flags.py")
    crf = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(crf)
    matrix = crf.readme_engine_matrix((root / "README.md").read_text())

    for name in zo:
        assert name in engine_action.choices
        assert name in _engines()
        assert _retention_model(name) in RETENTION_MODELS
        assert name in matrix, f"README engine matrix missing {name!r}"


def test_unknown_engine_error_names_known_engines():
    with pytest.raises(UnknownEngineError, match="mesp"):
        get_engine("definitely_not_an_engine")


def test_unsupported_quantize_combo_rejected():
    @register_engine("_quantless", backend="structured", quantize=("none",),
                     description="test-only engine without int8 support")
    def _build(spec, cfg, opt, policy):  # pragma: no cover - never built
        raise AssertionError("validation must fail before build_step")

    try:
        with pytest.raises(ValueError, match="_quantless"):
            TrainSpec(engine="_quantless", quantize="int8").validate()
    finally:
        unregister_engine("_quantless")


def test_mesp_seq_rejects_non_sgd():
    spec = TrainSpec(arch="qwen2.5-0.5b", reduced=True, engine="mesp_seq",
                     optimizer="adamw", steps=1)
    with pytest.raises(ValueError, match="mesp_seq"):
        Trainer.from_spec(spec)


# ------------------------------------------- no-core-edits extension point


def test_toy_engine_needs_no_core_edits(tmp_path):
    """Registering an engine in-test makes it a CLI choice, a benchmark
    sweep member and a trainable scenario — with zero edits to
    launch/train.py, benchmarks/run.py or models/*."""

    def _vag(params, cfg, batch, *, policy, key=None):
        from repro.core import mesp
        return mesp.value_and_grad(params, cfg, batch, policy=policy)

    @register_engine("_toy_halflr", backend="structured",
                     quantize=("none",), memsim="mesp", value_and_grad=_vag,
                     description="test-only: MeSP grads at half lr")
    def _build(spec, cfg, opt, policy):
        from repro.core import mesp

        def step(params, opt_state, batch):
            loss, grads = mesp.value_and_grad(params, cfg, batch,
                                              policy=policy)
            half = jax.tree_util.tree_map(
                lambda g: None if g is None else 0.5 * g, grads,
                is_leaf=lambda x: x is None)
            params, opt_state = opt.update(half, opt_state, params)
            return params, opt_state, loss

        return step

    try:
        # 1. appears in the launcher CLI choices (generated from registry)
        (engine_action,) = [a for a in build_arg_parser()._actions
                            if a.dest == "engine"]
        assert "_toy_halflr" in engine_action.choices

        # 2. appears in the benchmark sweep list (generated from registry)
        from benchmarks.run import _engines
        assert "_toy_halflr" in _engines()

        # 3. memsim resolves it through the registered hook
        from benchmarks.memsim import _retention_model
        assert _retention_model("_toy_halflr") == "mesp"

        # 4. trains end-to-end through the Trainer facade
        spec = TrainSpec(arch="qwen2.5-0.5b", reduced=True,
                         engine="_toy_halflr", lr=5e-2, steps=2, seq=16,
                         batch=2, ckpt_dir=str(tmp_path / "ckpt"))
        result = Trainer.from_spec(spec).fit()
        assert len(result.history) == 2
        assert jnp.isfinite(result.final_loss)
    finally:
        unregister_engine("_toy_halflr")


# -------------------------------------------------------- satellite guards


def test_mezo_engine_derives_key_from_spec_seed(tmp_path):
    """The mezo step folds its SPSA perturbation key from the spec's seed
    (regression: it used to hardcode PRNGKey(0))."""
    from repro.configs import get_config

    from repro.models import model as M

    cfg = get_config("qwen2.5-0.5b").reduced()
    params0 = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    def one_step(seed):
        # identical init params — only the spec's seed (→ SPSA key) varies
        spec = TrainSpec(engine="mezo", seed=seed, lr=1e-2, steps=1,
                         ckpt_dir=str(tmp_path / f"s{seed}"))
        tr = Trainer.from_spec(spec, cfg=cfg)
        params, _, _ = tr.step_fn(params0, tr.opt.init(params0), batch)
        return params

    p0a = one_step(0)
    p0b = one_step(0)
    p1 = one_step(7)
    l0a = jnp.concatenate([x.reshape(-1) for x in
                           jax.tree_util.tree_leaves(p0a)])
    l0b = jnp.concatenate([x.reshape(-1) for x in
                           jax.tree_util.tree_leaves(p0b)])
    l1 = jnp.concatenate([x.reshape(-1) for x in
                          jax.tree_util.tree_leaves(p1)])
    assert jnp.array_equal(l0a, l0b)
    assert not jnp.array_equal(l0a, l1)


def test_no_mode_kwarg_in_model_or_kernel_signatures():
    """Acceptance: the mode-string kwarg is gone from models/* and
    kernels/ops.py — ExecutionPolicy is the single threaded object."""
    from repro.kernels import ops
    from repro.models import griffin, layers, model, moe, rwkv6

    fns = [layers.apply_linear, layers.norm, layers.attention, layers.mlp,
           model.forward, model.loss_fn, model.dense_block, model.moe_block,
           moe.moe_mlp, griffin.recurrent_block, rwkv6.rwkv_block,
           ops.lora_linear, ops.rmsnorm, ops.sdpa]
    for fn in fns:
        assert "mode" not in inspect.signature(fn).parameters, fn


def test_mesh_axis_size_no_mesh_fallback():
    from repro.models import layers

    assert layers.mesh_axis_size(None) == 1
    assert layers.mesh_axis_size("model") == 1  # no mesh installed


def test_policy_is_static_and_hashable():
    pol = ExecutionPolicy(backend="pallas", quantize="int8")
    assert hash(pol) == hash(ExecutionPolicy(backend="pallas",
                                             quantize="int8"))
    with pytest.raises(ValueError, match="backend"):
        ExecutionPolicy(backend="nope")
