"""Paper Appendix A: hand-derived backward rules ≡ autodiff (the paper's
mathematical-equivalence claim, §5.5), including hypothesis property sweeps
(the sweeps degrade to a fixed parametrized sample when hypothesis is absent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import structured

TOL = dict(rtol=2e-5, atol=2e-5)


def _plain_lora(x, w0, a, b, bias, scale):
    y = x @ w0 + scale * ((x @ a) @ b)
    return y + bias if bias is not None else y


@pytest.mark.parametrize("bias", [False, True])
@pytest.mark.parametrize("shape", [(4, 8, 16), (2, 3, 5, 16)])
def test_lora_linear_matches_autodiff(bias, shape):
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    din, dout, r = shape[-1], 12, 4
    x = jax.random.normal(keys[0], shape)
    w0 = jax.random.normal(keys[1], (din, dout)) * 0.1
    a = jax.random.normal(keys[2], (din, r)) * 0.3
    b = jax.random.normal(keys[3], (r, dout)) * 0.3
    bias_v = jax.random.normal(keys[4], (dout,)) if bias else None

    def loss_s(x, a, b):
        return jnp.sum(jnp.sin(structured.lora_linear(x, w0, a, b, bias_v, 2.0)))

    def loss_p(x, a, b):
        return jnp.sum(jnp.sin(_plain_lora(x, w0, a, b, bias_v, 2.0)))

    v1, g1 = jax.value_and_grad(loss_s, (0, 1, 2))(x, a, b)
    v2, g2 = jax.value_and_grad(loss_p, (0, 1, 2))(x, a, b)
    np.testing.assert_allclose(v1, v2, **TOL)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, **TOL)


def test_lora_store_h_identical_gradients():
    """Table 5 ablation: store-h and recompute-h give identical grads."""
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(keys[0], (6, 16))
    w0 = jax.random.normal(keys[1], (16, 8)) * 0.1
    a = jax.random.normal(keys[2], (16, 4)) * 0.3
    b = jax.random.normal(keys[3], (4, 8)) * 0.3

    f1 = lambda x, a, b: jnp.sum(structured.lora_linear(x, w0, a, b, None, 2.0) ** 2)
    f2 = lambda x, a, b: jnp.sum(structured.lora_linear_store_h(x, w0, a, b, None, 2.0) ** 2)
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=1e-6, atol=1e-6)


def test_lora_batched_expert_weights():
    """MoE EP case: per-expert [E, ·, ·] weights get per-expert grads."""
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    E, C, d, f, r = 3, 8, 16, 12, 4
    x = jax.random.normal(keys[0], (E, C, d))
    w0 = jax.random.normal(keys[1], (E, d, f)) * 0.1
    a = jax.random.normal(keys[2], (E, d, r)) * 0.3
    b = jax.random.normal(keys[3], (E, r, f)) * 0.3

    f1 = lambda x, a, b: jnp.sum(jnp.tanh(structured.lora_linear(x, w0, a, b, None, 2.0)))
    f2 = lambda x, a, b: jnp.sum(jnp.tanh(x @ w0 + 2.0 * ((x @ a) @ b)))
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, **TOL)


def test_rmsnorm_matches_autodiff():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32,))

    def plain(x, w):
        rms = jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
        return jnp.sum(jnp.cos((x / rms) * w))

    def ours(x, w):
        return jnp.sum(jnp.cos(structured.rmsnorm(x, w, 1e-6)))

    g1 = jax.grad(ours, (0, 1))(x, w)
    g2 = jax.grad(plain, (0, 1))(x, w)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, **TOL)


@pytest.mark.parametrize("fn,plain", [
    (structured.silu, lambda x: x * jax.nn.sigmoid(x)),
    (structured.gelu, lambda x: jax.nn.gelu(x, approximate=True)),
])
def test_activations_match_autodiff(fn, plain):
    x = jnp.linspace(-4, 4, 64).reshape(8, 8)
    g1 = jax.grad(lambda x: jnp.sum(fn(x) ** 2))(x)
    g2 = jax.grad(lambda x: jnp.sum(plain(x) ** 2))(x)
    np.testing.assert_allclose(g1, g2, **TOL)


@pytest.mark.parametrize("window,causal", [(0, True), (3, True), (0, False)])
def test_sdpa_matches_autodiff(window, causal):
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, Hkv, N, D = 2, 4, 2, 16, 8
    q = jax.random.normal(keys[0], (B, H, N, D))
    k = jax.random.normal(keys[1], (B, Hkv, N, D))
    v = jax.random.normal(keys[2], (B, Hkv, N, D))

    def plain(q, k, v):
        out = structured._sdpa_ref(q, k, v, window, causal, 0, None)
        return jnp.sum(jnp.sin(out))

    def ours(q, k, v):
        return jnp.sum(jnp.sin(structured.sdpa(q, k, v, window, causal)))

    g1 = jax.grad(ours, (0, 1, 2))(q, k, v)
    g2 = jax.grad(plain, (0, 1, 2))(q, k, v)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, **TOL)


def test_softmax_xent_matches_autodiff_and_masks():
    logits = jax.random.normal(jax.random.PRNGKey(4), (2, 6, 11))
    labels = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0, 11)
    masked = labels.at[:, :2].set(-1)

    def plain(lg, lb):
        lp = jax.nn.log_softmax(lg, -1)
        valid = lb >= 0
        safe = jnp.where(valid, lb, 0)
        ll = jnp.take_along_axis(lp, safe[..., None], -1)[..., 0]
        return -jnp.sum(ll * valid) / jnp.maximum(jnp.sum(valid), 1)

    for lb in (labels, masked):
        v1, g1 = jax.value_and_grad(structured.softmax_xent)(logits, lb)
        v2, g2 = jax.value_and_grad(plain)(logits, lb)
        np.testing.assert_allclose(v1, v2, **TOL)
        np.testing.assert_allclose(g1, g2, **TOL)


# ----------------------------------------------------------------- property
def _check_lora_grad_equivalence(m, n, din, dout, r, scale, seed):
    """∀ shapes/scales: structured LoRA grads == autodiff grads."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(keys[0], (m, n, din))
    w0 = jax.random.normal(keys[1], (din, dout)) * 0.2
    a = jax.random.normal(keys[2], (din, r)) * 0.4
    b = jax.random.normal(keys[3], (r, dout)) * 0.4

    f1 = lambda a, b: jnp.sum(structured.lora_linear(x, w0, a, b, None, scale) ** 2)
    f2 = lambda a, b: jnp.sum((x @ w0 + scale * ((x @ a) @ b)) ** 2)
    g1 = jax.grad(f1, (0, 1))(a, b)
    g2 = jax.grad(f2, (0, 1))(a, b)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=5e-4, atol=5e-4)


def _check_rmsnorm_invariants(rows, d, seed):
    """RMSNorm output row-scale ≈ ||w||-bounded and grads match autodiff."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d)) * 3
    w = jnp.ones((d,))
    y = structured.rmsnorm(x, w, 1e-6)
    # invariant: mean-square of xhat == 1 (up to eps)
    ms = jnp.mean((y / w) ** 2, -1)
    np.testing.assert_allclose(ms, jnp.ones_like(ms), rtol=1e-3, atol=1e-3)


# Fixed-sample fallback (always runs, hypothesis or not): covers degenerate
# dims (1), non-square, rank extremes — the cases the sweep most often finds.
@pytest.mark.parametrize("m,n,din,dout,r,scale,seed", [
    (1, 1, 1, 1, 1, 0.25, 0),
    (4, 8, 16, 12, 4, 2.0, 1),
    (2, 3, 24, 1, 8, 4.0, 2),
    (6, 1, 1, 24, 2, 0.5, 3),
    (3, 5, 7, 11, 3, 1.0, 4),
])
def test_lora_grad_equivalence_sample(m, n, din, dout, r, scale, seed):
    _check_lora_grad_equivalence(m, n, din, dout, r, scale, seed)


@pytest.mark.parametrize("rows,d,seed", [(1, 2, 0), (8, 48, 1), (5, 7, 2)])
def test_rmsnorm_invariants_sample(rows, d, seed):
    _check_rmsnorm_invariants(rows, d, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 6), n=st.integers(1, 6), din=st.integers(1, 24),
        dout=st.integers(1, 24), r=st.integers(1, 8),
        scale=st.floats(0.25, 4.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_lora_grad_equivalence(m, n, din, dout, r, scale, seed):
        _check_lora_grad_equivalence(m, n, din, dout, r, scale, seed)

    @settings(max_examples=20, deadline=None)
    @given(rows=st.integers(1, 8), d=st.integers(2, 48),
           seed=st.integers(0, 2**31 - 1))
    def test_property_rmsnorm_invariants(rows, d, seed):
        _check_rmsnorm_invariants(rows, d, seed)
