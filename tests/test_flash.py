"""Pure-JAX flash attention (core/flash.py) ≡ dense structured sdpa."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import structured
from repro.core.flash import flash_attention


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * 0.5


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("nq,nk", [(128, 128), (96, 96)])
def test_flash_matches_dense(window, gqa, nq, nk):
    B, Hkv, D = 2, 2, 16
    H = Hkv * gqa
    q, k, v = _rand((B, H, nq, D), 0), _rand((B, Hkv, nk, D), 1), \
        _rand((B, Hkv, nk, D), 2)

    f = lambda q, k, v: jnp.sum(jnp.sin(
        flash_attention(q, k, v, window, True, 32, 32)))
    g = lambda q, k, v: jnp.sum(jnp.sin(structured.sdpa(q, k, v, window, True)))
    v1, g1 = jax.value_and_grad(f, (0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(g, (0, 1, 2))(q, k, v)
    np.testing.assert_allclose(v1, v2, rtol=2e-4, atol=2e-4)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    B, H, N, D = 1, 2, 64, 8
    q, k, v = _rand((B, H, N, D), 3), _rand((B, H, N, D), 4), _rand((B, H, N, D), 5)
    o1 = flash_attention(q, k, v, 0, False, 32, 32)
    o2 = structured.sdpa(q, k, v, 0, False)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_flash_long_window_linear_work():
    """Windowed flash visits only O(window) k-chunks per q-chunk — check the
    masked-out region contributes exactly zero gradient."""
    B, H, N, D, W = 1, 1, 256, 8, 32
    q, k, v = _rand((B, H, N, D), 6), _rand((B, H, N, D), 7), _rand((B, H, N, D), 8)
    g = jax.grad(lambda k: jnp.sum(
        flash_attention(q, k, v, W, True, 32, 32)[:, :, -1]))(k)
    # last query (position N-1) sees only keys in [N-W, N): earlier key grads 0
    np.testing.assert_allclose(g[:, :, :N - W], 0.0, atol=1e-7)
    assert float(jnp.max(jnp.abs(g[:, :, N - W:]))) > 0
