"""Pure-JAX flash attention (core/flash.py) ≡ dense structured sdpa, and
the Pallas kernels' sparse tile grids ≡ the dense-grid reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import structured
from repro.core.flash import flash_attention
from repro.kernels import flash_attention as fa
from repro.kernels.tiling import flash_schedule_stats


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * 0.5


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("nq,nk", [(128, 128), (96, 96)])
def test_flash_matches_dense(window, gqa, nq, nk):
    B, Hkv, D = 2, 2, 16
    H = Hkv * gqa
    q, k, v = _rand((B, H, nq, D), 0), _rand((B, Hkv, nk, D), 1), \
        _rand((B, Hkv, nk, D), 2)

    f = lambda q, k, v: jnp.sum(jnp.sin(
        flash_attention(q, k, v, window, True, 32, 32)))
    g = lambda q, k, v: jnp.sum(jnp.sin(structured.sdpa(q, k, v, window, True)))
    v1, g1 = jax.value_and_grad(f, (0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(g, (0, 1, 2))(q, k, v)
    np.testing.assert_allclose(v1, v2, rtol=2e-4, atol=2e-4)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    B, H, N, D = 1, 2, 64, 8
    q, k, v = _rand((B, H, N, D), 3), _rand((B, H, N, D), 4), _rand((B, H, N, D), 5)
    o1 = flash_attention(q, k, v, 0, False, 32, 32)
    o2 = structured.sdpa(q, k, v, 0, False)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_flash_long_window_linear_work():
    """Windowed flash visits only O(window) k-chunks per q-chunk — check the
    masked-out region contributes exactly zero gradient."""
    B, H, N, D, W = 1, 1, 256, 8, 32
    q, k, v = _rand((B, H, N, D), 6), _rand((B, H, N, D), 7), _rand((B, H, N, D), 8)
    g = jax.grad(lambda k: jnp.sum(
        flash_attention(q, k, v, W, True, 32, 32)[:, :, -1]))(k)
    # last query (position N-1) sees only keys in [N-W, N): earlier key grads 0
    np.testing.assert_allclose(g[:, :, :N - W], 0.0, atol=1e-7)
    assert float(jnp.max(jnp.abs(g[:, :, N - W:]))) > 0


# ---------------------------------------------------------------------------
# sparse tile grids (Pallas kernels, interpret mode): the flat live-tile
# schedule must be bit-equivalent to the dense-grid sweep of the same
# kernels on every mask shape — fwd, lse and all three gradients.
# ---------------------------------------------------------------------------

_I = dict(bq=128, bk=128, interpret=True)


def _kernel_io(nq, nk, gqa, seed=0):
    BHkv, D = 2, 32
    q = _rand((BHkv * gqa, nq, D), seed)
    k = _rand((BHkv, nk, D), seed + 1)
    v = _rand((BHkv, nk, D), seed + 2)
    g = _rand((BHkv * gqa, nq, D), seed + 3)
    return q, k, v, g


@pytest.mark.parametrize("nq,nk,causal,window,gqa", [
    (300, 300, True, 0, 2),      # causal, non-aligned, GQA
    (384, 384, True, 130, 1),    # sliding window crossing tile edges
    (260, 260, False, 0, 2),     # non-causal (all tiles live)
    (200, 200, True, 64, 4),     # window < block, wide GQA group
    (300, 260, True, 0, 2),      # Nq != Nk, both padded
])
def test_sparse_grid_matches_dense_grid(nq, nk, causal, window, gqa):
    q, k, v, g = _kernel_io(nq, nk, gqa)
    kw = dict(causal=causal, window=window, q_per_kv=gqa, **_I)
    o_s, l_s = fa.flash_attention_fwd(q, k, v, return_lse=True, sparse=True,
                                      **kw)
    o_d, l_d = fa.flash_attention_fwd(q, k, v, return_lse=True, sparse=False,
                                      **kw)
    np.testing.assert_allclose(o_s, o_d, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(l_s, l_d, rtol=2e-5, atol=2e-5)
    d_s = fa.flash_attention_bwd(q, k, v, o_s, l_s, g, sparse=True, **kw)
    d_d = fa.flash_attention_bwd(q, k, v, o_d, l_d, g, sparse=False, **kw)
    for u, w in zip(d_s, d_d):
        np.testing.assert_allclose(u, w, rtol=3e-5, atol=3e-5)


def test_sparse_grid_matches_structured_reference():
    """Sparse kernel grads == the dense jnp reference (structured.sdpa) on a
    non-aligned GQA shape — the end-to-end oracle, not just grid-vs-grid."""
    B, H, Hkv, N, D = 2, 4, 2, 200, 32
    q = _rand((B, H, N, D), 0)
    k, v = _rand((B, Hkv, N, D), 1), _rand((B, Hkv, N, D), 2)
    from repro.kernels import ops
    for causal, window in [(True, 0), (True, 96)]:
        f1 = lambda q, k, v: jnp.sum(jnp.sin(
            ops.flash_attention(q, k, v, causal, window, True)))
        f2 = lambda q, k, v: jnp.sum(jnp.sin(
            structured.sdpa(q, k, v, window, causal)))
        g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
        for u, w in zip(g1, g2):
            np.testing.assert_allclose(u, w, rtol=3e-5, atol=3e-5)


def test_sparse_grid_fully_masked_rows():
    """causal+window with Nq > Nk+window leaves whole q rows with no live
    tile: both grids must produce exactly 0 output and 0 gradients there
    (the dense jnp softmax NaNs on such rows — the kernels define them)."""
    nq, nk, W = 384, 128, 64
    q, k, v, g = _kernel_io(nq, nk, 1)
    kw = dict(causal=True, window=W, q_per_kv=1, **_I)
    o_s, l_s = fa.flash_attention_fwd(q, k, v, return_lse=True, sparse=True,
                                      **kw)
    o_d, l_d = fa.flash_attention_fwd(q, k, v, return_lse=True, sparse=False,
                                      **kw)
    np.testing.assert_allclose(o_s, o_d, rtol=2e-5, atol=2e-5)
    dead = nk + W  # rows >= nk + W attend to nothing
    assert float(jnp.max(jnp.abs(o_s[:, dead:]))) == 0.0
    d_s = fa.flash_attention_bwd(q, k, v, o_s, l_s, g, sparse=True, **kw)
    d_d = fa.flash_attention_bwd(q, k, v, o_d, l_d, g, sparse=False, **kw)
    for u, w in zip(d_s, d_d):
        np.testing.assert_allclose(u, w, rtol=3e-5, atol=3e-5)
    assert float(jnp.max(jnp.abs(d_s[0][:, dead:]))) == 0.0


def test_sparse_grid_live_tile_arithmetic():
    """Long causal sequences launch ~(n+1)/2n of the dense grid (+boundary
    diagonal); sliding windows launch O(window/N)."""
    st = flash_schedule_stats(2048, 2048, 128, 128, True, 0)
    n = st["dense_tiles"] ** 0.5          # 16 row blocks
    assert st["live_tiles"] == int(n * (n + 1) / 2)
    assert st["grid_fraction"] <= 0.5 + 1 / n + 1e-9
    assert st["boundary_tiles"] == int(n)  # the diagonal, everything else
    #                                        interior -> no mask evaluated
    stw = flash_schedule_stats(2048, 2048, 128, 128, True, 256)
    assert stw["grid_fraction"] <= 3 * 256 / 2048
    # non-causal, unpadded: every tile live, only edge tiles boundary
    stn = flash_schedule_stats(1024, 1024, 128, 128, False, 0)
    assert stn["grid_fraction"] == 1.0 and stn["boundary_tiles"] == 0
