"""Per-kernel validation: shape/dtype sweeps in interpret mode vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lora_fused import lora_dx, lora_fused
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_bwd
from repro.kernels.flash_attention import flash_attention_fwd

I = dict(interpret=True)


def _r(shape, seed, dtype=jnp.float32, scale=0.3):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
            ).astype(dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 8),
                                     (256, 384, 128, 16),
                                     (128, 256, 512, 4)])
def test_lora_fused_sweep(M, K, N, r, dtype, tol):
    x, w0 = _r((M, K), 0, dtype), _r((K, N), 1, dtype, 0.05)
    a, b = _r((K, r), 2, dtype), _r((r, N), 3, dtype)
    y = lora_fused(x, w0, a, b, 2.0, bm=128, bn=128, bk=128, **I)
    yref = ref.lora_fused_ref(x, w0, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 8), (128, 384, 256, 16)])
def test_lora_dx_sweep(M, K, N, r):
    g, w0 = _r((M, N), 0), _r((K, N), 1, scale=0.05)
    a, b = _r((K, r), 2), _r((r, N), 3)
    dx = lora_dx(g, w0, a, b, 2.0, **I)
    np.testing.assert_allclose(dx, ref.lora_dx_ref(g, w0, a, b, 2.0),
                               rtol=2e-5, atol=2e-5)


def test_lora_kernel_vjp_matches_structured():
    """Kernel wrapper grads == structured (paper A.1) grads."""
    from repro.core import structured
    x, w0 = _r((4, 64, 128), 0), _r((128, 128), 1, scale=0.05)
    a, b = _r((128, 8), 2), _r((8, 128), 3)
    f1 = lambda x, a, b: jnp.sum(jnp.sin(
        ops.lora_linear_kernel(x, w0, a, b, 2.0, True)))
    f2 = lambda x, a, b: jnp.sum(jnp.sin(
        structured.lora_linear(x, w0, a, b, None, 2.0)))
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("M,d", [(256, 128), (512, 384)])
def test_rmsnorm_sweep(M, d, dtype, tol):
    x, w = _r((M, d), 0, dtype, 2.0), _r((d,), 1, dtype, 1.0)
    y = rmsnorm(x, w, 1e-6, **I)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref.rmsnorm_ref(x, w), np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_bwd():
    x, w, g = _r((256, 128), 0, scale=2.0), _r((128,), 1, scale=1.0), \
        _r((256, 128), 2)
    dx, dw = rmsnorm_bwd(x, w, g, 1e-6, **I)
    dx_r, dw_r = ref.rmsnorm_bwd_ref(x, w, g)
    np.testing.assert_allclose(dx, dx_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(dw, dw_r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_kernel_sweep(causal, window, dtype, tol):
    BH, N, D = 4, 256, 64
    q, k, v = _r((BH, N, D), 0, dtype), _r((BH, N, D), 1, dtype), \
        _r((BH, N, D), 2, dtype)
    o = flash_attention_fwd(q, k, v, causal=causal, window=window,
                            bq=128, bk=128, **I)
    oref = ref.flash_attention_ref(q[None], k[None], v[None],
                                   causal=causal, window=window)[0]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_gqa_wrapper():
    B, H, Hkv, N, D = 2, 8, 2, 128, 32
    q = _r((B, H, N, D), 0)
    k, v = _r((B, Hkv, N, D), 1), _r((B, Hkv, N, D), 2)
    o = ops.flash_attention_kernel(q, k, v, bq=128, bk=128, interpret=True)
    kr = jnp.repeat(k, H // Hkv, 1)
    vr = jnp.repeat(v, H // Hkv, 1)
    oref = ref.flash_attention_ref(q, kr, vr)
    np.testing.assert_allclose(o, oref, rtol=2e-5, atol=2e-5)
