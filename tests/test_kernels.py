"""Per-kernel validation: shape/dtype sweeps in interpret mode vs ref.py.
Non-block-aligned shapes exercise the padding wrappers (tiling.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import structured
from repro.kernels import ops, ref
from repro.kernels.lora_fused import lora_dab, lora_dx, lora_fused
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_bwd
from repro.kernels.flash_attention import (flash_attention_bwd,
                                           flash_attention_fwd)

I = dict(interpret=True)


def _r(shape, seed, dtype=jnp.float32, scale=0.3):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
            ).astype(dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 8),
                                     (256, 384, 128, 16),
                                     (128, 256, 512, 4),
                                     (96, 160, 112, 8),    # nothing aligned
                                     (1, 160, 7, 4)])      # degenerate rows
def test_lora_fused_sweep(M, K, N, r, dtype, tol):
    x, w0 = _r((M, K), 0, dtype), _r((K, N), 1, dtype, 0.05)
    a, b = _r((K, r), 2, dtype), _r((r, N), 3, dtype)
    y = lora_fused(x, w0, a, b, 2.0, bm=128, bn=128, bk=128, **I)
    yref = ref.lora_fused_ref(x, w0, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,K,N,r", [(128, 128, 128, 8), (128, 384, 256, 16),
                                     (96, 160, 112, 8)])
def test_lora_dx_sweep(M, K, N, r):
    g, w0 = _r((M, N), 0), _r((K, N), 1, scale=0.05)
    a, b = _r((K, r), 2), _r((r, N), 3)
    dx = lora_dx(g, w0, a, b, 2.0, **I)
    np.testing.assert_allclose(dx, ref.lora_dx_ref(g, w0, a, b, 2.0),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("M,K,N,r", [(256, 128, 128, 8), (96, 160, 112, 8),
                                     (300, 384, 256, 16)])
def test_lora_dab_fused(M, K, N, r):
    """One-pass dA/dB == the A.1 eq 10/12 contractions (h recomputed)."""
    x, g = _r((M, K), 0), _r((M, N), 1)
    a, b = _r((K, r), 2), _r((r, N), 3)
    da, db = lora_dab(x, g, a, b, 2.0, bm=128, **I)
    dh = (2.0 * g) @ b.T
    np.testing.assert_allclose(da, x.T @ dh, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(db, (x @ a).T @ (2.0 * g),
                               rtol=2e-5, atol=2e-5)


def test_lora_kernel_vjp_matches_structured():
    """Kernel wrapper grads == structured (paper A.1) grads."""
    from repro.core import structured
    x, w0 = _r((4, 64, 128), 0), _r((128, 128), 1, scale=0.05)
    a, b = _r((128, 8), 2), _r((8, 128), 3)
    f1 = lambda x, a, b: jnp.sum(jnp.sin(
        ops.lora_linear_kernel(x, w0, a, b, 2.0, True)))
    f2 = lambda x, a, b: jnp.sum(jnp.sin(
        structured.lora_linear(x, w0, a, b, None, 2.0)))
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("M,d", [(256, 128), (512, 384), (100, 160)])
def test_rmsnorm_sweep(M, d, dtype, tol):
    x, w = _r((M, d), 0, dtype, 2.0), _r((d,), 1, dtype, 1.0)
    y = rmsnorm(x, w, 1e-6, **I)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref.rmsnorm_ref(x, w), np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,d", [(256, 128), (100, 160)])
def test_rmsnorm_bwd(M, d):
    x, w, g = _r((M, d), 0, scale=2.0), _r((d,), 1, scale=1.0), _r((M, d), 2)
    dx, dw = rmsnorm_bwd(x, w, g, 1e-6, **I)
    dx_r, dw_r = ref.rmsnorm_bwd_ref(x, w, g)
    np.testing.assert_allclose(dx, dx_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(dw, dw_r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("N", [256, 200])   # 200: padded + masked tail
def test_flash_kernel_sweep(N, causal, window, dtype, tol):
    BH, D = 4, 64
    q, k, v = _r((BH, N, D), 0, dtype), _r((BH, N, D), 1, dtype), \
        _r((BH, N, D), 2, dtype)
    o = flash_attention_fwd(q, k, v, causal=causal, window=window,
                            bq=128, bk=128, **I)
    oref = ref.flash_attention_ref(q[None], k[None], v[None],
                                   causal=causal, window=window)[0]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_fwd_lse_matches_oracle():
    """The saved per-row logsumexp must equal core/flash.py's (it drives the
    backward's probability recompute)."""
    from repro.core import flash as flash_ref
    BH, N, D = 2, 192, 32
    q, k, v = _r((BH, N, D), 0), _r((BH, N, D), 1), _r((BH, N, D), 2)
    _, lse = flash_attention_fwd(q, k, v, causal=True, bq=128, bk=128,
                                 return_lse=True, **I)
    _, lse_ref = flash_ref._fwd_impl(q[None, :, None], k[None], v[None],
                                     0, True, 128, 128)
    np.testing.assert_allclose(lse, lse_ref[0, :, 0], rtol=1e-5, atol=1e-5)


def test_flash_kernel_gqa_wrapper():
    """GQA via kernel index maps (no jnp.repeat of K/V in HBM)."""
    B, H, Hkv, N, D = 2, 8, 2, 128, 32
    q = _r((B, H, N, D), 0)
    k, v = _r((B, Hkv, N, D), 1), _r((B, Hkv, N, D), 2)
    o = ops.flash_attention_kernel(q, k, v, bq=128, bk=128, interpret=True)
    kr = jnp.repeat(k, H // Hkv, 1)
    vr = jnp.repeat(v, H // Hkv, 1)
    oref = ref.flash_attention_ref(q, kr, vr)
    np.testing.assert_allclose(o, oref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_kernel_vjp_matches_structured(causal, window):
    """Kernel flash backward (lse-driven) == structured sdpa grads, GQA +
    non-aligned seq included."""
    B, H, Hkv, N, D = 2, 4, 2, 200, 32
    q = _r((B, H, N, D), 0)
    k, v = _r((B, Hkv, N, D), 1), _r((B, Hkv, N, D), 2)
    f1 = lambda q, k, v: jnp.sum(jnp.sin(
        ops.flash_attention(q, k, v, causal, window, True)))
    f2 = lambda q, k, v: jnp.sum(jnp.sin(
        structured.sdpa(q, k, v, window, causal)))
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=3e-5, atol=3e-5)


def test_flash_bwd_kernel_direct():
    """flash_attention_bwd standalone against jax.grad of the oracle."""
    BH, N, D = 2, 160, 32
    q, k, v = _r((BH, N, D), 0), _r((BH, N, D), 1), _r((BH, N, D), 2)
    out, lse = flash_attention_fwd(q, k, v, causal=True, bq=128, bk=128,
                                   return_lse=True, **I)
    g = _r((BH, N, D), 3)
    dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, g, causal=True,
                                     bq=128, bk=128, **I)
    f = lambda q, k, v: jnp.sum(
        ref.flash_attention_ref(q[None], k[None], v[None])[0] * g)
    dq_r, dk_r, dv_r = jax.grad(f, (0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, dq_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(dk, dk_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(dv, dv_r, rtol=3e-5, atol=3e-5)
