"""Multi-tenant serving subsystem (``repro.serve``) end-to-end.

Covers the three components and their composition:

* :class:`AdapterStore` — LRU residency, pinning, eviction, the stacked
  tenant-axis layout the grouped decode path consumes, byte accounting;
* :class:`PagedKVAllocator` — reserve/free ledger, rejection, peak tracking;
* :class:`ContinuousBatcher` — admission counters, recycling, and the two
  correctness contracts: a request's token stream is *identical* under any
  arrival interleaving (placement independence), and equals the
  single-request scalar-decode oracle run with that tenant's adapters
  merged into a plain (unstacked) parameter tree.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import (AdapterStore, ContinuousBatcher, PagedKVAllocator,
                         Request, StoreFull, synthetic_adapters)

CFG = get_config("qwen2.5-0.5b").reduced()


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def _batcher(params, n_tenants=4, capacity=3, slots=8, tile=2, max_len=32):
    store = AdapterStore(params, capacity=capacity)
    bat = ContinuousBatcher(CFG, store, slots=slots, tile=tile,
                            max_len=max_len, page_size=8)
    for i in range(n_tenants):
        bat.register_adapter(f"u{i}", synthetic_adapters(params, i))
    return bat, store


def _reqs(n, n_tenants, prompt_len=3, max_new=5):
    return [Request(f"r{i}", f"u{i % n_tenants}",
                    tuple(1 + (2 * i + j) % 89 for j in range(prompt_len)),
                    max_new) for i in range(n)]


# ---------------------------------------------------------------- allocator


def test_paged_allocator_ledger():
    al = PagedKVAllocator(n_pages=4, page_size=8)
    assert al.pages_for(1) == 1 and al.pages_for(8) == 1
    assert al.pages_for(9) == 2
    assert al.reserve("a", 17)                 # 3 pages
    assert al.used_pages == 3 and al.free_tokens == 8
    assert not al.reserve("b", 9)              # needs 2, only 1 free
    assert al.counters["rejected"] == 1
    assert al.reserve("b", 8)
    assert al.counters["peak_pages"] == 4
    with pytest.raises(KeyError):
        al.reserve("a", 1)                     # double reservation
    al.free("a")
    assert al.used_pages == 1 and al.counters["freed"] == 3
    assert al.can_reserve(24)


# -------------------------------------------------------------------- store


def test_store_stacks_tenant_axis_before_matrix_dims(params):
    store = AdapterStore(params, capacity=3)
    blk = store.params["blocks"]["attn"]["q"]
    base = params["blocks"]["attn"]["q"]
    # layer-stacked [L, d, r] -> [L, R, d, r]: scan slices layers first,
    # leaving the [R, ., .] shape apply_linear routes on
    assert blk["a"].shape == base["a"].shape[:-2] + (3,) + base["a"].shape[-2:]
    assert blk["w"].shape == base["w"].shape        # frozen leaves shared
    assert store.slot_bytes > 0
    assert store.allocated_bytes == 3 * store.slot_bytes


def test_store_lru_eviction_and_pinning(params):
    store = AdapterStore(params, capacity=2)
    adapters = {u: synthetic_adapters(params, i)
                for i, u in enumerate(["u0", "u1", "u2"])}
    s0 = store.acquire("u0", adapters["u0"], pin=False)
    store.acquire("u1", adapters["u1"], pin=False)
    store.acquire("u0", adapters["u0"], pin=False)     # refresh u0's recency
    assert store.counters["hits"] == 1
    s2 = store.acquire("u2", adapters["u2"], pin=False)
    assert s2 == store._slot_of["u2"]
    assert store.lookup("u1") is None                  # u1 was LRU, evicted
    assert store.lookup("u0") == s0                    # u0 survived
    assert store.counters["evictions"] == 1
    # slot content actually belongs to the new tenant
    a_stack = store.params["blocks"]["attn"]["q"]["a"]
    want = adapters["u2"]["blocks"]["attn"]["q"]["a"]
    np.testing.assert_array_equal(np.asarray(a_stack[:, s2]),
                                  np.asarray(want))


def test_store_pin_blocks_eviction(params):
    store = AdapterStore(params, capacity=2)
    store.acquire("u0", synthetic_adapters(params, 0))          # pinned
    store.acquire("u1", synthetic_adapters(params, 1))          # pinned
    assert not store.can_admit("u2")
    with pytest.raises(StoreFull):
        store.acquire("u2", synthetic_adapters(params, 2))
    store.release("u1")
    assert store.can_admit("u2")
    store.acquire("u2", synthetic_adapters(params, 2))
    assert store.lookup("u1") is None


def test_store_rejects_moe_and_missing_leaves(params):
    moe_cfg = get_config("olmoe-1b-7b").reduced()
    moe_params = M.init_params(jax.random.PRNGKey(0), moe_cfg)
    with pytest.raises(ValueError, match="MoE"):
        AdapterStore(moe_params, capacity=2)
    with pytest.raises(ValueError, match="missing LoRA"):
        AdapterStore(params, capacity=1).acquire(
            "u0", {"blocks": {}})


def test_synthetic_adapters_deterministic_and_distinct(params):
    a0 = synthetic_adapters(params, 0)
    a0b = synthetic_adapters(params, 0)
    a1 = synthetic_adapters(params, 1)
    leaf = lambda t: t["blocks"]["attn"]["q"]["a"]
    np.testing.assert_array_equal(np.asarray(leaf(a0)), np.asarray(leaf(a0b)))
    assert float(jnp.abs(leaf(a0) - leaf(a1)).max()) > 0
    # frozen leaves pass through untouched
    np.testing.assert_array_equal(
        np.asarray(a0["blocks"]["attn"]["q"]["w"]),
        np.asarray(params["blocks"]["attn"]["q"]["w"]))


# ----------------------------------------------------------------- batcher


def test_serve_end_to_end_counters(params):
    bat, store = _batcher(params, n_tenants=4, capacity=3)
    reqs = _reqs(8, 4)
    results = bat.run(reqs)
    assert set(results) == {r.rid for r in reqs}
    assert all(len(v) == 5 for v in results.values())
    c = bat.counters
    assert c["admitted"] == c["completed"] == 8
    assert c["decoded_tokens"] == 8 * 5
    assert c["prefill_tokens"] == 8 * 3
    assert store.counters["evictions"] >= 1        # 4 tenants, 3 slots
    assert bat.alloc.used_pages == 0               # everything recycled
    assert bat.alloc.counters["reserved"] == bat.alloc.counters["freed"]
    assert bat.active == 0 and not bat.queue


def test_serve_deterministic_across_interleavings(params):
    reqs = _reqs(8, 4)
    streams = []
    for order in (reqs, list(reversed(reqs)), reqs[1::2] + reqs[0::2]):
        bat, _ = _batcher(params, n_tenants=4, capacity=3)
        streams.append(bat.run(order))
    for rid in streams[0]:
        assert streams[0][rid] == streams[1][rid] == streams[2][rid], rid


def test_serve_matches_scalar_decode_oracle(params):
    """Each served stream equals a single-request greedy decode with the
    tenant's adapters merged into a plain (unstacked) tree — no batching,
    no grouped kernel, no store."""
    from repro.serve.store import _adapter_leaves
    bat, _ = _batcher(params, n_tenants=3, capacity=3)
    reqs = _reqs(5, 3, prompt_len=4, max_new=4)
    results = bat.run(reqs)

    def merged(adapters):
        leaves = _adapter_leaves(adapters)

        def pick(path, leaf):
            return leaves.get(jax.tree_util.keystr(path), leaf)
        return jax.tree_util.tree_map_with_path(pick, params)

    step = jax.jit(lambda p, c, t: M.decode_step(p, CFG, c, t))
    for req in reqs:
        p = merged(synthetic_adapters(params, int(req.adapter[1:])))
        cache = M.init_cache(CFG, 1, 32)
        out = []
        tok = None
        for t in req.prompt:
            logits, cache = step(p, cache, jnp.asarray([[t]], jnp.int32))
            tok = int(jnp.argmax(logits[0, 0]))
        out.append(tok)
        while len(out) < req.max_new:
            logits, cache = step(p, cache,
                                 jnp.asarray([[out[-1]]], jnp.int32))
            out.append(int(jnp.argmax(logits[0, 0])))
        assert results[req.rid] == out, req.rid


def test_serve_admission_rejections(params):
    # 1 tile of 2 rows, 2 pages: the second tenant cannot co-reside
    store = AdapterStore(params, capacity=1)
    bat = ContinuousBatcher(CFG, store, slots=2, tile=2, max_len=16,
                            page_size=8)
    for i in range(2):
        bat.register_adapter(f"u{i}", synthetic_adapters(params, i))
    reqs = _reqs(4, 2, prompt_len=2, max_new=3)
    results = bat.run(reqs)
    assert len(results) == 4                       # all drain eventually
    c = bat.counters
    assert c["rejected_tiles"] > 0                 # u1 waited for the tile
    assert store.counters["evictions"] >= 1


def test_serve_validates_requests(params):
    bat, _ = _batcher(params, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        bat.submit(Request("big", "u0", tuple(range(1, 10)), 10))
    with pytest.raises(KeyError, match="not registered"):
        bat.submit(Request("x", "nobody", (1, 2), 2))
    with pytest.raises(ValueError, match="multiple"):
        ContinuousBatcher(CFG, AdapterStore(params, 1), slots=5, tile=2)


def test_per_slot_cache_unsupported_families():
    cfg = get_config("rwkv6-1.6b").reduced()
    with pytest.raises(ValueError, match="per_slot"):
        M.init_cache(cfg, 2, 16, per_slot=True)
    moe_cfg = get_config("olmoe-1b-7b").reduced()
    moe_params = M.init_params(jax.random.PRNGKey(0), moe_cfg)
    cache = M.init_cache(moe_cfg, 2, 16, per_slot=True)   # moe cache is fine
    with pytest.raises(ValueError, match="adapter routing unsupported"):
        M.decode_step(moe_params, moe_cfg, cache,
                      jnp.ones((2, 1), jnp.int32),
                      adapter_tiles=jnp.zeros(1, jnp.int32))
