"""Grouped LoRA kernel family (``kernels/lora_grouped.py``) end-to-end.

Three layers of guarantees, mirroring test_quant_mode's structure:

1. **Equivalence**: the grouped kernel (one launch, per-tile adapter gather
   by scalar-prefetched index) matches the per-adapter Python loop it
   replaces — forward and all gradients (x, A, B) ≤1e-5 relative — across
   ragged group sizes, empty groups, a single group, non-tile-aligned
   feature dims, and int8 frozen bases.
2. **Routing**: ``lora_grouped_decode`` (the serving path: shared base +
   stacked adapters, runtime int32 tile routing) matches the gather
   reference for arbitrary — including repeated and non-contiguous —
   slot assignments, and re-routing does not retrace the jitted step.
3. **Lifecycle**: on the quantized grouped path no dense float W0-shaped
   array is ever produced outside ``pallas_call`` — dequantization happens
   tile-wise in VMEM, so MoE/multi-tenant serving never pays an HBM
   [E, K, N] float materialization. Plus the model-level contract: a
   pallas-mode MoE forward/backward (bf16-f32 and int8 bases, expert
   linears routed through the grouped kernel) matches structured mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import mesp, quant
from repro.kernels import ops, tiling
from repro.models import model as M

# deliberately non-tile-aligned: K=72, N=88 are not multiples of the 128
# lane block (nor of 8); r=6 is an odd rank
K, N, R = 72, 88, 6


def _mats(E, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w0 = jax.random.normal(ks[0], (E, K, N)) * 0.1
    a = jax.random.normal(ks[1], (E, K, R)) * 0.3
    b = jax.random.normal(ks[2], (E, R, N)) * 0.3
    return w0, a, b


def _loop_ref(x, sizes, w0, a, b, scale=2.0):
    """The per-adapter loop the grouped kernel replaces: slice each group's
    rows, dense matmul + 2-D LoRA with its own (A, B)."""
    outs, off = [], 0
    for g, s in enumerate(sizes):
        if s == 0:
            continue
        xg = x[off:off + s]
        wg = quant.maybe_dequant(
            {"q": w0["q"][g], "scale": w0["scale"][g]}
            if quant.is_quantized(w0) else w0[g], x.dtype)
        outs.append(xg @ wg + scale * ((xg @ a[g]) @ b[g]))
        off += s
    if not outs:
        return jnp.zeros((0, b.shape[-1]), x.dtype)
    return jnp.concatenate(outs)


def _rel(u, v):
    fu = jnp.concatenate([t.reshape(-1) for t in jax.tree_util.tree_leaves(u)])
    fv = jnp.concatenate([t.reshape(-1) for t in jax.tree_util.tree_leaves(v)])
    return float(jnp.linalg.norm(fu - fv) /
                 jnp.maximum(jnp.linalg.norm(fv), 1e-30))


# ------------------------------------------------------------- equivalence


@pytest.mark.parametrize("sizes", [
    (5, 11, 3),            # ragged, nothing bm-aligned
    (8, 0, 13, 0, 2),      # empty groups interleaved
    (17,),                 # E = 1 degenerates to a plain LoRA linear
    (0, 0, 9),             # leading groups empty
])
@pytest.mark.parametrize("quantized", [False, True])
def test_ragged_matches_per_adapter_loop(sizes, quantized):
    E = len(sizes)
    w0, a, b = _mats(E)
    if quantized:
        q, s = quant.quantize_int8(w0)
        w0 = {"q": q, "scale": s}
    x = jax.random.normal(jax.random.PRNGKey(9), (sum(sizes), K)) * 0.3

    def f_grouped(x, a, b):
        y = ops.lora_grouped_ragged(x, sizes, w0, a, b, 2.0)
        return jnp.sum(jnp.tanh(y)), y

    def f_loop(x, a, b):
        y = _loop_ref(x, sizes, w0, a, b)
        return jnp.sum(jnp.tanh(y)), y

    (lg, yg), gg = jax.value_and_grad(f_grouped, (0, 1, 2),
                                      has_aux=True)(x, a, b)
    (ll, yl), gl = jax.value_and_grad(f_loop, (0, 1, 2),
                                      has_aux=True)(x, a, b)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yl),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(lg), float(ll), rtol=1e-6, atol=1e-6)
    assert _rel(gg, gl) <= 1e-5
    # dA rows of empty groups are exactly zero (no tiles launched for them)
    for g, sz in enumerate(sizes):
        if sz == 0:
            assert float(jnp.abs(gg[1][g]).max()) == 0.0
            assert float(jnp.abs(gg[2][g]).max()) == 0.0


@pytest.mark.parametrize("quantized", [False, True])
def test_moe_shape_matches_loop(quantized):
    """The batched-uniform [E, C, ·] entry point (MoE expert linears)."""
    E, C = 3, 13
    w0, a, b = _mats(E, seed=2)
    if quantized:
        q, s = quant.quantize_int8(w0)
        w0 = {"q": q, "scale": s}
    x = jax.random.normal(jax.random.PRNGKey(4), (E, C, K)) * 0.3

    def f_grouped(x, a, b):
        return jnp.sum(jnp.tanh(ops.lora_grouped_linear(x, w0, a, b, 2.0)))

    def f_loop(x, a, b):
        y = _loop_ref(x.reshape(E * C, K), (C,) * E, w0, a, b)
        return jnp.sum(jnp.tanh(y))

    lg, gg = jax.value_and_grad(f_grouped, (0, 1, 2))(x, a, b)
    ll, gl = jax.value_and_grad(f_loop, (0, 1, 2))(
        x, a, b)
    np.testing.assert_allclose(float(lg), float(ll), rtol=1e-6)
    assert _rel((gg[0].reshape(E * C, K), gg[1], gg[2]),
                (gl[0], gl[1], gl[2])) <= 1e-5


def test_schedule_pack_unpack_roundtrip():
    sizes, bm = (5, 0, 11, 2), 8
    x = jax.random.normal(jax.random.PRNGKey(0), (sum(sizes), 7))
    xp = tiling.pack_ragged_rows(x, sizes, bm)
    gid, offs = tiling.grouped_schedule(sizes, bm)
    assert xp.shape[0] == int(offs[-1]) == len(gid) * bm
    assert list(gid) == [0, 2, 2, 3]          # empty group 1 launches nothing
    np.testing.assert_array_equal(
        np.asarray(tiling.unpack_ragged_rows(xp, sizes, bm)), np.asarray(x))
    stats = tiling.grouped_schedule_stats(sizes, bm)
    assert stats["live_tiles"] == 4 and stats["empty_groups"] == 1
    assert stats["dense_tiles"] == len(sizes) * 2   # cmax=11 -> 2 tiles each
    assert stats["grid_fraction"] == pytest.approx(0.5)


# ----------------------------------------------------------------- routing


@pytest.mark.parametrize("quantized", [False, True])
def test_decode_runtime_routing_matches_reference(quantized):
    """Serving path: stacked adapters + shared base, tile_gid routed at
    runtime (repeated + non-contiguous slots), pallas vs gather reference."""
    from repro.api.policy import ExecutionPolicy
    Rslots, bm, Mrows = 5, 8, 48
    w0, a, b = _mats(Rslots, seed=7)
    w0 = w0[0]                                # shared base [K, N]
    if quantized:
        q, s = quant.quantize_int8(w0)
        w0 = {"q": q, "scale": s}
    x = jax.random.normal(jax.random.PRNGKey(11), (Mrows, K)) * 0.3
    pol = ExecutionPolicy(backend="pallas")
    step = jax.jit(lambda x, g: ops.lora_grouped_decode(
        x, w0, a, b, g, None, 2.0, bm=bm, policy=pol))
    for gid in ([3, 3, 0, 4, 1, 2], [0, 0, 0, 0, 0, 0], [4, 2, 4, 2, 4, 2]):
        g = jnp.asarray(gid, jnp.int32)
        ref = ops.lora_grouped_decode(x, w0, a, b, g, None, 2.0, bm=bm,
                                      policy=None)   # jnp gather reference
        np.testing.assert_allclose(np.asarray(step(x, g)), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # runtime routing: all three gid vectors reused ONE compiled step
    assert step._cache_size() == 1


def test_decode_rejects_unaligned_rows():
    w0, a, b = _mats(2)
    x = jnp.zeros((10, K))
    with pytest.raises(ValueError, match="not a multiple"):
        ops.lora_grouped_decode(x, w0[0], a, b, jnp.zeros(2, jnp.int32),
                                bm=8)


# --------------------------------------------------------------- lifecycle


from tests.test_quant_mode import _float_w0_shapes  # noqa: E402


def test_no_dense_expert_w0_on_grouped_quant_path():
    """fwd+bwd of the quantized grouped op never materialize a float
    [E, K, N] (or per-expert [K, N]) array outside pallas_call — the
    per-tile dequant is the whole point of the int8 grouped kernel."""
    E, C = 3, 16
    w0, a, b = _mats(E, seed=5)
    q, s = quant.quantize_int8(w0)
    x = jax.random.normal(jax.random.PRNGKey(6), (E, C, K)) * 0.3

    def loss(x, a, b):
        y = ops.lora_grouped_linear(x, {"q": q, "scale": s}, a, b, 2.0,
                                    interpret=True)
        return jnp.sum(y * y)

    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1, 2)))(x, a, b)
    hits = _float_w0_shapes(jaxpr.jaxpr, {(E, K, N), (K, N), (N, K)})
    assert not hits, f"dense W0 materialized outside kernels: {hits}"


def test_structured_moe_fallback_does_materialize_w0():
    """Sanity for the guard above: the structured dequant fallback *does*
    produce the dense [E, K, N]."""
    E = 3
    w0, a, b = _mats(E, seed=5)
    q, s = quant.quantize_int8(w0)
    x = jax.random.normal(jax.random.PRNGKey(6), (E, 16, K)) * 0.3

    def loss(x, a, b):
        w = quant.dequantize_int8(q, s, x.dtype)
        return jnp.sum(jnp.square(x @ w + 2.0 * ((x @ a) @ b)))

    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1, 2)))(x, a, b)
    assert _float_w0_shapes(jaxpr.jaxpr, {(E, K, N)})


@pytest.mark.parametrize("quantize", [None, "int8"])
def test_moe_model_pallas_matches_structured(quantize):
    """Model-level contract: pallas-mode MoE (expert linears through the
    grouped kernel, int8 dequant-in-VMEM included) reproduces structured
    mode's loss and LoRA gradients."""
    cfg = get_config("olmoe-1b-7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg, quantize=quantize)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l_s, g_s = mesp.value_and_grad(params, cfg, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(params, cfg, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-5)
    assert _rel(g_p, g_s) <= 1e-5
