"""MeZO baseline behaviour + Table 3 gradient-quality analysis machinery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import gradcheck, mesp, mezo
from repro.models import model as M


def _setup():
    cfg = get_config("qwen2.5-0.5b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    return cfg, params, {"tokens": tokens, "labels": tokens}


def test_spsa_is_unbiased_direction_on_average():
    """Averaged over many z, SPSA correlates positively with the true grad
    (single-sample correlation ≈ 0 — the paper's Table 3 finding)."""
    cfg, params, batch = _setup()
    _, g_true = mesp.value_and_grad(params, cfg, batch)
    acc = None
    n = 24
    for i in range(n):
        _, g_est = mezo.spsa_grad(params, cfg, batch, jax.random.PRNGKey(i))
        acc = g_est if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, g_est)
    acc = jax.tree_util.tree_map(lambda g: g / n, acc)
    m_avg = gradcheck.gradient_metrics(acc, g_true)
    m_one = gradcheck.gradient_metrics(
        mezo.spsa_grad(params, cfg, batch, jax.random.PRNGKey(0))[1], g_true)
    # single estimate: near-zero correlation (Table 3); average: clearly > 0
    assert abs(float(m_one["cosine_sim"])) < 0.25
    assert float(m_avg["cosine_sim"]) > float(abs(m_one["cosine_sim"]))


def test_mezo_step_changes_only_lora():
    cfg, params, batch = _setup()
    p1, loss = mezo.train_step(params, cfg, batch, jax.random.PRNGKey(7), 1e-3)
    assert jnp.isfinite(loss)
    mask = M.trainable_mask(params)
    for m, (a, b) in zip(jax.tree_util.tree_leaves(mask),
                         zip(jax.tree_util.tree_leaves(params),
                             jax.tree_util.tree_leaves(p1))):
        if not m:
            np.testing.assert_array_equal(a, b)


def test_gradient_metrics_sanity():
    import pytest
    g = {"a": jnp.arange(8.0)}
    m = gradcheck.gradient_metrics(g, g)
    assert float(m["cosine_sim"]) == pytest.approx(1.0, abs=1e-5)
    assert float(m["sign_agree"]) == 1.0
    assert float(m["rel_error"]) == pytest.approx(0.0, abs=1e-6)
    m2 = gradcheck.gradient_metrics(
        {"a": -jnp.arange(8.0)}, g)
    assert float(m2["cosine_sim"]) == pytest.approx(-1.0, abs=1e-5)
