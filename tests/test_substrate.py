"""Data pipeline, optimizers, checkpointing, fault tolerance, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, load_checkpoint, \
    save_checkpoint
from repro.data import DataState, make_batch_iterator, synthetic_corpus
from repro.optim import adamw, compression, sgd, sgd_momentum
from repro.optim.schedules import constant, inverse_sqrt, warmup_cosine
from repro.runtime.elastic import make_mesh_from_devices, rebalance_batch, \
    reshard_tree
from repro.runtime.fault_tolerance import RestartRequired, StragglerPolicy, \
    run_resilient


# ----------------------------------------------------------------- data
def test_data_determinism_and_resume():
    it1 = make_batch_iterator(100, 8, 4, n_tokens=4096, seed=3)
    batches = [next(it1) for _ in range(5)]
    # restart from saved state after 3 batches
    it2 = make_batch_iterator(100, 8, 4, n_tokens=4096, seed=3)
    for _ in range(3):
        next(it2)
    state = DataState.from_dict(it2.state.to_dict())
    it3 = make_batch_iterator(100, 8, 4, n_tokens=4096, seed=3, state=state)
    for i in (3, 4):
        b = next(it3)
        np.testing.assert_array_equal(b["tokens"], batches[i]["tokens"])


def test_data_host_sharding_disjoint():
    full = synthetic_corpus(50, 1 << 14, seed=0)
    b0 = next(make_batch_iterator(50, 8, 8, host_index=0, host_count=2,
                                  corpus=full))
    b1 = next(make_batch_iterator(50, 8, 8, host_index=1, host_count=2,
                                  corpus=full))
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    it = make_batch_iterator(100, 16, 2, n_tokens=4096)
    b = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ----------------------------------------------------------------- optim
def _quadratic_params():
    return {"w": {"a": jnp.array([3.0, -2.0]), "b": jnp.array([1.5])},
            "frozen": jnp.array([7.0])}


def _quadratic_grads(p):
    return {"w": {"a": 2 * p["w"]["a"], "b": 2 * p["w"]["b"]}, "frozen": None}


@pytest.mark.parametrize("opt", [sgd(0.1), sgd_momentum(0.05), adamw(0.1)])
def test_optimizers_converge_and_respect_none(opt):
    p = _quadratic_params()
    state = opt.init(p)
    for _ in range(60):
        p, state = opt.update(_quadratic_grads(p), state, p)
    assert float(jnp.abs(p["w"]["a"]).max()) < 0.2
    assert float(p["frozen"][0]) == 7.0  # None grad => untouched


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.array(5))) == pytest.approx(0.5)
    assert float(s(jnp.array(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(s(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(inverse_sqrt(1.0, 16)(jnp.array(64))) == pytest.approx(0.5)
    assert float(constant(0.3)(jnp.array(9))) == pytest.approx(0.3)


def test_gradient_compression_bf16_roundtrip():
    g = {"x": jnp.linspace(-1, 1, 64), "skip": None}
    gc = compression.from_bf16(compression.to_bf16(g))
    np.testing.assert_allclose(gc["x"], g["x"], rtol=1e-2, atol=1e-2)


def test_topk_error_feedback_conserves_signal():
    g = {"x": jnp.arange(1.0, 9.0)}
    sent1, err = compression.topk_sparsify(g, 0.25)
    assert int(jnp.sum(sent1["x"] != 0)) == 2
    # error feedback: nothing is lost — sent_total + residual == n·g exactly
    total = sent1["x"]
    n = 24
    for _ in range(n - 1):
        sent, err = compression.topk_sparsify(g, 0.25, err)
        total = total + sent["x"]
    np.testing.assert_allclose(total + err["x"], n * g["x"], rtol=1e-5)
    # and the time-average converges toward g
    np.testing.assert_allclose(total / n, g["x"], atol=0.5)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path)
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))}
    opt = {"step": jnp.array(4, jnp.int32)}
    for s in (10, 20, 30, 40):
        save_checkpoint(d, s, params, opt, {"cursor": s}, keep=2)
    assert latest_step(d) == 40
    # retention: only 2 newest kept
    assert sorted(int(p.split("_")[1]) for p in os.listdir(d)
                  if p.startswith("step_")) == [30, 40]
    p2, o2, ds, _ = load_checkpoint(d, 40, params, opt)
    np.testing.assert_array_equal(p2["w"], params["w"])
    assert int(o2["step"]) == 4
    assert ds["cursor"] == 40


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path)
    params = {"w": jnp.ones((4,))}
    path = save_checkpoint(d, 1, params)
    # corrupt the array file
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, fn))
    np.save(os.path.join(path, fn), arr + 1)
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(d, 1, params)


# ---------------------------------------------------------- fault tolerance
def test_run_resilient_recovers_from_injected_failure(tmp_path):
    it = make_batch_iterator(50, 4, 2, n_tokens=2048)
    ckpt = Checkpointer(str(tmp_path), interval=2)
    calls = {"n": 0}

    def step_fn(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 5:  # injected failure mid-training
            raise RuntimeError("simulated device loss")
        return params + 1, opt_state, float(params)

    params, _, results = run_resilient(
        step_fn, lambda: (jnp.array(0.0), None), it, ckpt, total_steps=8)
    assert len(results) == 8 and results[-1].step == 8
    # resumed from the step-4 checkpoint: final params == 8 steps applied
    assert float(params) == 8.0


def test_straggler_policy():
    sp = StragglerPolicy(factor=2.0, consecutive_limit=2)
    assert sp.observe(1.0) == "ok"
    assert sp.observe(1.1) == "ok"
    assert sp.observe(5.0) == "slow"
    assert sp.observe(5.0) == "restart"


def test_straggler_triggers_restart_in_driver(tmp_path):
    import time as _t
    it = make_batch_iterator(50, 4, 2, n_tokens=2048)
    ckpt = Checkpointer(str(tmp_path), interval=100)
    times = iter([0.01, 0.01, 0.01, 1.0, 1.0, 1.0])

    def step_fn(params, opt_state, batch):
        _t.sleep(next(times, 0.01))
        return params, opt_state, 0.0

    with pytest.raises(RestartRequired):
        run_resilient(step_fn, lambda: (jnp.array(0.0), None), it, ckpt,
                      total_steps=6,
                      straggler=StragglerPolicy(factor=3.0,
                                                consecutive_limit=2))


# ----------------------------------------------------------------- elastic
def test_elastic_mesh_and_reshard():
    devs = jax.devices()
    mesh = make_mesh_from_devices(devs, model_parallel=1)
    from jax.sharding import PartitionSpec as P
    tree = {"w": jnp.arange(8.0), "skip": None}
    specs = {"w": P(), "skip": None}
    out = reshard_tree(tree, mesh, specs)
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert rebalance_batch(256, 16, 8) == 32
    with pytest.raises(ValueError, match="cannot be kept invariant"):
        rebalance_batch(256, 16, 7)


# ------------------------------------------------------------------- quant
def test_int8_quantization_roundtrip():
    from repro.core import quant
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.2
    q, s = quant.quantize_int8(w)
    assert q.dtype == jnp.int8
    wd = quant.dequantize_int8(q, s, jnp.float32)
    np.testing.assert_allclose(wd, w, atol=float(2 * np.abs(w).max() / 127))


def test_quantize_frozen_skips_lora():
    from repro.core import quant
    params = {"attn": {"q": {"w": jnp.ones((8, 8)),
                             "a": jnp.ones((8, 2)), "b": jnp.zeros((2, 8))}}}
    qp = quant.quantize_frozen(params)
    assert "q" in qp["attn"]["q"]["w"]           # frozen weight quantized
    assert qp["attn"]["q"]["a"].dtype == jnp.float32  # LoRA untouched
    w = quant.maybe_dequant(qp["attn"]["q"]["w"], jnp.float32)
    np.testing.assert_allclose(w, params["attn"]["q"]["w"], atol=0.02)
