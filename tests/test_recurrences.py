"""Cross-path equivalence for the recurrent families: the chunkwise-parallel
train path must agree with the token-by-token decode recurrence — the
strongest invariant these implementations have (hypothesis-swept; a fixed
parametrized sample stands in when hypothesis is absent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.models import griffin, rwkv6


def _check_wkv_chunked_equals_stepwise(n, h, d, seed):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, n, h, d))
    k = jax.random.normal(ks[1], (B, n, h, d)) * 0.5
    v = jax.random.normal(ks[2], (B, n, h, d)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, n, h, d)) * 0.3 - 1.0)
    u = jax.random.normal(ks[4], (h, d)) * 0.2

    y_par, s_par = rwkv6.wkv_chunked(r, k, v, logw, u,
                                     jnp.zeros((B, h, d, d), jnp.float32))
    # sequential reference via the decode step
    s = jnp.zeros((B, h, d, d), jnp.float32)
    ys = []
    for t in range(n):
        y, s = rwkv6.wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, s)
        ys.append(y)
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_par, s, rtol=2e-4, atol=2e-4)


def _check_rg_lru_scan_equals_stepwise(n, w, seed):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (B, n, w))
    gr = jax.random.normal(ks[1], (B, n, w))
    gi = jax.random.normal(ks[2], (B, n, w))
    lam = jnp.full((w,), 1.5)

    y_par, _ = griffin.rg_lru(x, gr, gi, lam, None)
    state = jnp.zeros((B, w), jnp.float32)
    ys = []
    for t in range(n):
        y, state = griffin.rg_lru(x[:, t:t + 1], gr[:, t:t + 1],
                                  gi[:, t:t + 1], lam, state)
        ys.append(y[:, 0])
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-4, atol=2e-4)


# Fixed-sample fallback: chunk-boundary cases (n < chunk, n == chunk+1, odd n).
@pytest.mark.parametrize("n,h,d,seed", [
    (2, 1, 4, 0), (17, 2, 8, 1), (33, 1, 8, 2), (40, 2, 4, 3)])
def test_wkv_chunked_equals_stepwise_sample(n, h, d, seed):
    _check_wkv_chunked_equals_stepwise(n, h, d, seed)


@pytest.mark.parametrize("n,w,seed", [(2, 4, 0), (31, 16, 1), (50, 4, 2)])
def test_rg_lru_scan_equals_stepwise_sample(n, w, seed):
    _check_rg_lru_scan_equals_stepwise(n, w, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 40), h=st.sampled_from([1, 2]),
           d=st.sampled_from([4, 8]), seed=st.integers(0, 10**6))
    def test_wkv_chunked_equals_stepwise(n, h, d, seed):
        _check_wkv_chunked_equals_stepwise(n, h, d, seed)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 50), w=st.sampled_from([4, 16]),
           seed=st.integers(0, 10**6))
    def test_rg_lru_scan_equals_stepwise(n, w, seed):
        _check_rg_lru_scan_equals_stepwise(n, w, seed)


def test_rwkv_block_decode_matches_forward():
    """Running the rwkv block over a sequence token-by-token (decode path)
    must equal the chunked full-sequence forward."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("rwkv6-1.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, N = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, cfg.vocab)
    full_logits = M.forward(params, cfg, tokens)

    cache = M.init_cache(cfg, B, N + 4)
    outs = []
    for t in range(N):
        logits, cache = M.decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, 1)
    np.testing.assert_allclose(dec_logits, full_logits, rtol=5e-3, atol=5e-3)


def test_dense_decode_matches_forward():
    """KV-cached decode ≡ full forward for the dense family."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("granite-8b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, N = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, cfg.vocab)
    full_logits = M.forward(params, cfg, tokens)

    cache = M.init_cache(cfg, B, N + 4)
    outs = []
    for t in range(N):
        logits, cache = M.decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, 1)
    np.testing.assert_allclose(dec_logits, full_logits, rtol=5e-3, atol=5e-3)


def test_hybrid_decode_matches_forward():
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("recurrentgemma-2b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, N = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, cfg.vocab)
    full_logits = M.forward(params, cfg, tokens)
    cache = M.init_cache(cfg, B, N + 4)
    outs = []
    for t in range(N):
        logits, cache = M.decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full_logits,
                               rtol=5e-3, atol=5e-3)


def test_gemma3_ring_cache_decode_matches_forward():
    """Windowed layers use a ring-buffer KV cache; decode must still equal
    the full forward (positions > window exercise the wraparound)."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("gemma3-12b").reduced()   # window 8 on local layers
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, N = 2, 20                                # > 2× window: full wrap
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, cfg.vocab)
    full_logits = M.forward(params, cfg, tokens)

    cache = M.init_cache(cfg, B, N + 4)
    # local-layer caches must be ring-sized (window slots, not N+4)
    k_shape = jax.tree_util.tree_leaves(cache["groups"])[1].shape
    outs = []
    for t in range(N):
        logits, cache = M.decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full_logits,
                               rtol=5e-3, atol=5e-3)
