"""Telemetry subsystem tests: the zero-cost disabled path, the JSONL event
schema, metric registry namespacing, the memory watermark vs the memsim
prediction, fleet shard-merge determinism, and the typed-event timeline of
a chaos run through ``Trainer.fit``."""
import dataclasses
import json
import os
import random

import pytest

from repro.api import Trainer, TrainSpec
from repro.telemetry import (DISABLED, CounterGroup, MemoryWatermark,
                             MetricRegistry, NULL_SPAN, SCHEMA_VERSION,
                             StepEvent, Telemetry)
from repro.telemetry import events as ev
from repro.telemetry import spans as sp
from repro.runtime.degrade import WatermarkTrigger
from repro.runtime.guard import REASONS, StepGuard


def _tiny_spec(tmp_path, **kw):
    base = dict(arch="qwen2.5-0.5b", reduced=True, engine="mesp",
                steps=3, seq=32, batch=2, quiet=True,
                ckpt_dir=str(tmp_path / "ckpt"))
    base.update(kw)
    return TrainSpec(**base)


# ----------------------------------------------------- disabled = zero cost
def test_disabled_singleton_is_inert():
    assert DISABLED.enabled is False
    assert DISABLED.sinks == []
    # the same shared no-op span object every call — no allocation
    assert DISABLED.span("a") is DISABLED.span("b") is NULL_SPAN
    DISABLED.emit(StepEvent(step=1, loss=0.5, seconds=0.1))   # no-op
    assert DISABLED.events() == []
    assert DISABLED.counts_by_kind() == {}


def test_disabled_fit_never_touches_telemetry_machinery(tmp_path,
                                                        monkeypatch):
    """With --telemetry off the loop must run the exact pre-telemetry code:
    no span enters, no record is built. Poison both paths and fit."""
    def boom(*a, **k):
        raise AssertionError("telemetry machinery invoked on disabled path")

    monkeypatch.setattr(sp.Tracer, "span", boom)
    monkeypatch.setattr(ev, "to_record", boom)
    spec = _tiny_spec(tmp_path)
    tr = Trainer.from_spec(spec)
    step_fn_before = tr.step_fn
    result = tr.fit()
    assert len(result.history) == 3
    # the jitted step object is the one built at spec time — telemetry
    # added no wrapper around it
    assert tr.step_fn is step_fn_before
    assert "registry" not in result.metrics
    assert not (tmp_path / "ckpt" / "telemetry").exists()


# ------------------------------------------------------------ event schema
def test_event_round_trip_and_validation():
    for kind, cls in ev.EVENT_TYPES.items():
        event = cls()
        rec = ev.to_record(event, seq=3, worker=1, ts=123.5)
        assert rec["v"] == SCHEMA_VERSION
        assert rec["kind"] == kind
        assert (rec["ts"], rec["seq"], rec["worker"]) == (123.5, 3, 1)
        assert ev.validate_record(rec) == []
        assert ev.from_record(rec) == event


def test_validate_record_catches_drift():
    rec = ev.to_record(StepEvent(step=1, loss=2.0, seconds=0.1), seq=0)
    bad = dict(rec, v=99)
    assert any("schema version" in e for e in ev.validate_record(bad))
    bad = {k: v for k, v in rec.items() if k != "loss"}
    assert any("missing field 'loss'" in e for e in ev.validate_record(bad))
    bad = dict(rec, surprise=1)
    assert any("unexpected field 'surprise'" in e
               for e in ev.validate_record(bad))
    assert any("unknown kind" in e
               for e in ev.validate_record(dict(rec, kind="meteor")))


def test_jsonl_sink_round_trip(tmp_path):
    tel = Telemetry(enabled=True, out_dir=str(tmp_path))
    for i in range(4):
        tel.emit(StepEvent(step=i, loss=1.0 / (i + 1), seconds=0.01))
    tel.close()
    recs = ev.read_jsonl(str(tmp_path / "events.jsonl"))
    assert len(recs) == 4
    assert [r["seq"] for r in recs] == [0, 1, 2, 3]
    assert all(ev.validate_record(r) == [] for r in recs)
    # in-memory sink saw the same records
    assert tel.events("step") == recs


# -------------------------------------------------------- metrics registry
def test_counter_group_is_dict_compatible():
    g = CounterGroup("pages", ("reserved", "freed"))
    g["reserved"] += 3
    g.counter("freed").inc()
    assert dict(g) == {"reserved": 3, "freed": 1}
    assert g.namespaced() == {"pages.reserved": 3, "pages.freed": 1}
    g.update({k: 0 for k in g})          # the benchmark warmup-reset idiom
    assert dict(g) == {"reserved": 0, "freed": 0}


def test_registry_unifies_groups_and_scalars():
    reg = MetricRegistry()
    pages = CounterGroup("pages", ("reserved",))
    reg.register_group(pages)
    pages["reserved"] += 2
    reg.counter("ckpt.saves").inc()
    reg.gauge("train.loss").set(0.25)
    reg.histogram("train.step_seconds").record(0.02)
    snap = reg.snapshot()
    assert snap["pages.reserved"] == 2
    assert snap["ckpt.saves"] == 1
    assert snap["train.loss"] == 0.25
    assert snap["train.step_seconds"]["count"] == 1


def test_paged_allocator_counters_namespaced():
    from repro.serve.paged import PagedKVAllocator
    alloc = PagedKVAllocator(n_pages=4, page_size=8)
    assert alloc.reserve("a", 20)        # 3 pages
    assert not alloc.reserve("b", 16)    # 2 > 1 free -> rejected
    alloc.free("a")
    reg = MetricRegistry()
    reg.register_group(alloc.counters)
    snap = reg.snapshot()
    assert snap["pages.reserved"] == 3
    assert snap["pages.rejected"] == 1
    assert snap["pages.freed"] == 3


def test_autotune_cache_counters(monkeypatch):
    import jax.numpy as jnp
    from repro.kernels import autotune
    # isolate the module-global measured cache (autotune() is in-memory
    # only — save_cache() is explicit — so a dict copy restores it)
    monkeypatch.setattr(autotune, "_CACHE", dict(autotune._CACHE))
    autotune.COUNTERS.update({k: 0 for k in autotune.COUNTERS})
    autotune.choose_blocks("flash", Nq=256, Nk=256, D=64)   # heuristic: miss
    autotune.autotune("flash", lambda blocks: jnp.zeros(()),
                      candidates=[{"bq": 256, "bk": 256}],
                      repeats=1, Nq=256, Nk=256, D=64)
    autotune.choose_blocks("flash", Nq=256, Nk=256, D=64)   # measured: hit
    stats = autotune.cache_stats()
    assert stats["cache_miss"] >= 1
    assert stats["cache_hit"] >= 1
    assert stats["sweeps"] == 1
    assert stats["sweep_candidates"] == 1


# ------------------------------------------------------------------- spans
def test_tracer_nesting_and_chrome_export(tmp_path):
    tr = sp.Tracer(enabled=True)
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    assert [n for n, *_ in tr.finished] == ["inner", "outer"]
    path = str(tmp_path / "trace.json")
    tr.save(path)
    with open(path) as f:
        events = json.load(f)["traceEvents"]
    assert {e["name"] for e in events} == {"outer", "inner"}
    assert all(e["ph"] == "X" for e in events)
    inner = next(e for e in events if e["name"] == "inner")
    assert inner["args"]["depth"] == 1
    totals = tr.totals()
    assert totals["outer"]["count"] == 1


# -------------------------------------------------------- watermark trigger
def test_watermark_trigger_hysteresis():
    trig = WatermarkTrigger(budget_mb=100.0)   # threshold 0.9 -> 90 MB
    assert [trig.observe(v) for v in (50, 95, 95, 50)] == \
        [False, False, True, False]
    assert trig.trips == 1
    # re-armed: two more consecutive over-limit samples trip again
    assert [trig.observe(v) for v in (95, 95)] == [False, True]
    assert trig.trips == 2


def test_watermark_trigger_rejects_zero_budget():
    with pytest.raises(ValueError):
        WatermarkTrigger(budget_mb=0.0)


# ------------------------------------------------------------- guard events
def test_guard_by_reason_counts_and_events():
    tel = Telemetry(enabled=True)
    guard = StepGuard(budget=8, warmup=1, telemetry=tel)
    assert guard.observe(1.0) == "accept"
    assert guard.observe(float("nan")) == "reject"
    assert guard.observe(1.0e9) == "reject"            # spike vs EWMA ~1.0
    st = guard.state()
    assert st["accepted"] == 1 and st["rejected"] == 2
    assert st["by_reason"]["nonfinite_loss"] == 1
    assert st["by_reason"]["loss_spike"] == 1
    assert set(st["by_reason"]) == set(REASONS)
    reasons = [r["reason"] for r in tel.events("guard")]
    assert reasons == ["nonfinite_loss", "loss_spike"]
    snap = tel.registry.snapshot()
    assert snap["guard.reject.nonfinite_loss"] == 1
    assert snap["guard.loss_ewma"] == 1.0


# ----------------------------------------------- enabled fit, end to end
def test_fit_telemetry_watermark_vs_memsim(tmp_path):
    tdir = str(tmp_path / "tele")
    spec = _tiny_spec(tmp_path, telemetry="on", telemetry_dir=tdir)
    result = Trainer.from_spec(spec).fit()
    m = result.metrics
    wm = m["watermark"]
    assert wm["measured_peak_mb"] > 0
    assert wm["predicted_peak_mb"] > 0          # memsim reduced-cfg peak
    assert wm["source"] in ("device_stats", "live_arrays")
    assert wm["samples"] == 3
    assert m["events_by_kind"]["step"] == 3
    assert m["events_by_kind"]["run"] == 2      # start + end
    assert m["events_by_kind"]["watermark"] == 3
    assert m["registry"]["train.steps"] == 3
    assert m["spans"]["step"]["count"] == 3
    # files on disk: schema-valid JSONL + a Chrome trace
    recs = ev.read_jsonl(os.path.join(tdir, "events.jsonl"))
    assert all(ev.validate_record(r) == [] for r in recs)
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "run" and kinds[-1] == "run"
    assert os.path.exists(os.path.join(tdir, "trace.json"))


def test_chaos_fit_emits_typed_timeline(tmp_path):
    """Injected faults, ladder rungs and guard rejections must all appear
    as typed events in the JSONL timeline (the chaos-smoke CI contract)."""
    tdir = str(tmp_path / "tele")
    spec = _tiny_spec(tmp_path, steps=8, telemetry="on", telemetry_dir=tdir,
                      inject_faults="oom@2,nan@4", max_retries=4)
    result = Trainer.from_spec(spec).fit()
    assert len(result.history) == 8
    kinds = result.metrics["events_by_kind"]
    assert kinds.get("fault", 0) >= 2           # injector fire + loop handle
    assert kinds.get("degrade", 0) >= 1         # oom walked the ladder
    assert kinds.get("guard", 0) >= 1           # nan rejected
    recs = ev.read_jsonl(os.path.join(tdir, "events.jsonl"))
    assert all(ev.validate_record(r) == [] for r in recs)
    faults = [r for r in recs if r["kind"] == "fault"]
    assert any(r["source"] == "injector" and r["injected"] for r in faults)
    assert any(r["source"] == "loop" for r in faults)
    degr = [r for r in recs if r["kind"] == "degrade"]
    assert degr and degr[0]["trigger"] == "oom"
    guards = [r for r in recs if r["kind"] == "guard"]
    assert guards[0]["reason"] == "nonfinite_loss"


def test_mem_budget_triggers_proactive_degrade(tmp_path):
    """A tiny --mem-budget-mb must trip the watermark trigger (live_arrays
    residency exceeds it immediately) and degrade BEFORE any OOM."""
    tdir = str(tmp_path / "tele")
    spec = _tiny_spec(tmp_path, steps=6, telemetry="on", telemetry_dir=tdir,
                      mem_budget_mb=0.05)
    result = Trainer.from_spec(spec).fit()
    assert result.counters.watermark_triggers >= 1
    assert result.counters.oom_events == 0
    assert result.degradations                 # a rung was applied
    recs = ev.read_jsonl(os.path.join(tdir, "events.jsonl"))
    degr = [r for r in recs if r["kind"] == "degrade"]
    assert degr and degr[0]["trigger"] == "watermark"


# -------------------------------------------------------------- fleet merge
def test_fleet_shard_merge_is_deterministic(tmp_path):
    """Merged fleet timeline must be byte-identical regardless of shard
    file order (workers finish in arbitrary order)."""
    shards = []
    for w in range(3):
        path = str(tmp_path / f"worker_{w}.jsonl")
        sink = ev.JsonlSink(path)
        for i in range(4):
            sink.emit(ev.to_record(StepEvent(step=i, loss=1.0, seconds=0.01),
                                   seq=i, worker=w, ts=100.0 + i + 0.1 * w))
        sink.close()
        shards.append(path)
    outs = []
    for trial in range(3):
        order = list(shards)
        random.Random(trial).shuffle(order)
        out = str(tmp_path / f"merged_{trial}.jsonl")
        ev.merge_jsonl_shards(order, out)
        with open(out, "rb") as f:
            outs.append(f.read())
    assert outs[0] == outs[1] == outs[2]
    merged = ev.read_jsonl(str(tmp_path / "merged_0.jsonl"))
    assert len(merged) == 12
    keys = [(r["ts"], str(r["worker"]), r["seq"]) for r in merged]
    assert keys == sorted(keys)


def test_merge_fleet_telemetry_helper(tmp_path):
    from repro.launch.fleet import merge_fleet_telemetry
    assert merge_fleet_telemetry(str(tmp_path)) is None   # no shards yet
    sink = ev.JsonlSink(str(tmp_path / "worker_0.jsonl"))
    sink.emit(ev.to_record(StepEvent(step=0), seq=0, worker=0, ts=1.0))
    sink.close()
    out = merge_fleet_telemetry(str(tmp_path))
    assert out == str(tmp_path / "fleet.jsonl")
    assert len(ev.read_jsonl(out)) == 1


# ---------------------------------------------------------------- CLI flags
def test_telemetry_flags_cli_round_trip():
    spec = TrainSpec(telemetry="on", telemetry_dir="/tmp/t", profile="off",
                     mem_budget_mb=12.5, quiet=True)
    parsed = TrainSpec.from_cli_args(spec.to_cli_args())
    assert parsed.telemetry == "on"
    assert parsed.telemetry_dir == "/tmp/t"
    assert parsed.mem_budget_mb == 12.5
    assert parsed.quiet is True
    with pytest.raises(ValueError):
        TrainSpec(telemetry="maybe").validate()
    with pytest.raises(ValueError):
        TrainSpec(mem_budget_mb=-1.0).validate()


def test_memwatch_sample_and_compare():
    import jax.numpy as jnp
    keep = jnp.ones((256, 1024), jnp.float32)     # 1 MB pinned live
    mw = MemoryWatermark()
    s = mw.sample()
    assert s["source"] in ("device_stats", "live_arrays")
    assert s["measured_mb"] >= 1.0                # at least `keep`
    mw.predicted_mb = 2 * mw.peak_mb
    cmp = mw.compare()
    assert cmp["samples"] == 1
    assert 0 < cmp["ratio"] <= 0.5 + 1e-9
    del keep
