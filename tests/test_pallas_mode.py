"""End-to-end ``mode="pallas"``: a full MeSP train step through the kernel
dispatch layer must produce gradients identical (≤1e-5 rel.) to the
structured jnp path and to plain autodiff — including on shapes not
divisible by the kernel block sizes (the padding wrappers' contract).

Kernels run under interpret mode on the CPU test platform (dispatch decides
automatically via ``ops.pallas_interpret``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import mesp
from repro.kernels import ops
from repro.models import model as M

# Deliberately non-tile-aligned: d_model 160, d_ff 192, vocab 97, seq 96
# (M = batch·seq = 192 rows through the linears, 96 query rows — none of the
# feature dims are multiples of the 128 block size). f32 so 1e-5 is meaningful.
CFG = ArchConfig(name="pallas-test", family="dense", n_layers=2, d_model=160,
                 n_heads=4, n_kv_heads=2, d_ff=192, vocab=97,
                 qkv_bias=True, dtype="float32")  # bias: qwen-style path


def _batch(seq=96, batch=2):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                                CFG.vocab)
    return {"tokens": tokens, "labels": tokens}


def _flat(tree):
    return jnp.concatenate([t.reshape(-1).astype(jnp.float32)
                            for t in jax.tree_util.tree_leaves(tree)])


def _rel(a, b):
    fa, fb = _flat(a), _flat(b)
    return float(jnp.linalg.norm(fa - fb) /
                 jnp.maximum(jnp.linalg.norm(fb), 1e-30))


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def test_pallas_uses_kernel_attention():
    """seq 96 >= the dispatch threshold: the kernel path must be active,
    not silently falling back (guards the equivalence tests' coverage)."""
    assert 96 >= ops.PALLAS_ATTN_MIN_SEQ
    q = jnp.zeros((2, 4, 96, 40))
    k = jnp.zeros((2, 2, 96, 40))
    assert ops.attention_supported(q, k)


@pytest.mark.parametrize("seq", [96, 48])
def test_pallas_grads_match_structured(params, seq):
    """seq 96 exercises the flash kernel; seq 48 exercises the attention
    fallback with kernel linears/norms (both below any block multiple)."""
    batch = _batch(seq=seq)
    l_s, g_s = mesp.value_and_grad(params, CFG, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(params, CFG, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-6)
    assert _rel(g_p, g_s) <= 1e-5


def test_pallas_grads_match_plain_autodiff(params):
    """The ultimate oracle: framework autodiff of the plain forward."""
    batch = _batch()
    _, g_plain = mesp.value_and_grad(params, CFG, batch, mode="plain")
    _, g_p = mesp.value_and_grad(params, CFG, batch, mode="pallas")
    assert _rel(g_p, g_plain) <= 1e-5


def test_pallas_train_step_runs_and_descends(params):
    batch = _batch()
    p, l0 = mesp.train_step(params, CFG, batch, 5e-2, mode="pallas")
    for _ in range(3):
        p, l = mesp.train_step(p, CFG, batch, 5e-2, mode="pallas")
    assert float(l) < float(l0)


def test_pallas_step_equals_structured_step(params):
    """One SGD step in each mode must land on the same parameters."""
    batch = _batch()
    p_s, _ = mesp.train_step(params, CFG, batch, 1e-2, mode="structured")
    p_p, _ = mesp.train_step(params, CFG, batch, 1e-2, mode="pallas")
    for a, b in zip(jax.tree_util.tree_leaves(p_p),
                    jax.tree_util.tree_leaves(p_s)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


def test_pallas_grads_match_structured_quantized():
    """``quantize=int8`` composes with mode="pallas": the dequant-in-VMEM
    kernels and the structured dequant fallback agree ≤1e-5 on the same
    non-tile-aligned shapes (full suite in test_quant_mode.py)."""
    qp = M.init_params(jax.random.PRNGKey(0), CFG, quantize="int8")
    batch = _batch()
    l_s, g_s = mesp.value_and_grad(qp, CFG, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(qp, CFG, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-6)
    assert _rel(g_p, g_s) <= 1e-5


def test_dispatch_falls_back_on_unsupported():
    """MoE-style batched [E,·,·] weights take the structured path (and still
    deliver correct gradients through the dispatcher)."""
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    E, C, d, f, r = 2, 8, 16, 12, 4
    x = jax.random.normal(keys[0], (E, C, d))
    w0 = jax.random.normal(keys[1], (E, d, f)) * 0.1
    a = jax.random.normal(keys[2], (E, d, r)) * 0.3
    b = jax.random.normal(keys[3], (E, r, f)) * 0.3
    assert not ops.lora_supported(x, w0)
    f1 = lambda x, a, b: jnp.sum(jnp.tanh(ops.lora_linear(x, w0, a, b, None, 2.0)))
    f2 = lambda x, a, b: jnp.sum(jnp.tanh(x @ w0 + 2.0 * ((x @ a) @ b)))
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=2e-5, atol=2e-5)
