"""End-to-end ``mode="pallas"``: a full MeSP train step through the kernel
dispatch layer must produce gradients identical (≤1e-5 rel.) to the
structured jnp path and to plain autodiff — including on shapes not
divisible by the kernel block sizes (the padding wrappers' contract).

Kernels run under interpret mode on the CPU test platform (dispatch decides
automatically via ``ops.pallas_interpret``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import mesp
from repro.kernels import ops
from repro.models import model as M

# Deliberately non-tile-aligned: d_model 160, d_ff 192, vocab 97, seq 96
# (M = batch·seq = 192 rows through the linears, 96 query rows — none of the
# feature dims are multiples of the 128 block size). f32 so 1e-5 is meaningful.
CFG = ArchConfig(name="pallas-test", family="dense", n_layers=2, d_model=160,
                 n_heads=4, n_kv_heads=2, d_ff=192, vocab=97,
                 qkv_bias=True, dtype="float32")  # bias: qwen-style path


def _batch(seq=96, batch=2):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                                CFG.vocab)
    return {"tokens": tokens, "labels": tokens}


def _flat(tree):
    return jnp.concatenate([t.reshape(-1).astype(jnp.float32)
                            for t in jax.tree_util.tree_leaves(tree)])


def _rel(a, b):
    fa, fb = _flat(a), _flat(b)
    return float(jnp.linalg.norm(fa - fb) /
                 jnp.maximum(jnp.linalg.norm(fb), 1e-30))


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def test_pallas_uses_kernel_attention():
    """seq 96 >= the dispatch threshold: the kernel path must be active,
    not silently falling back (guards the equivalence tests' coverage)."""
    assert 96 >= ops.PALLAS_ATTN_MIN_SEQ
    q = jnp.zeros((2, 4, 96, 40))
    k = jnp.zeros((2, 2, 96, 40))
    assert ops.attention_supported(q, k)


@pytest.mark.parametrize("seq", [96, 48])
def test_pallas_grads_match_structured(params, seq):
    """seq 96 exercises the flash kernel; seq 48 exercises the attention
    fallback with kernel linears/norms (both below any block multiple)."""
    batch = _batch(seq=seq)
    l_s, g_s = mesp.value_and_grad(params, CFG, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(params, CFG, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-6)
    assert _rel(g_p, g_s) <= 1e-5


def test_pallas_grads_match_plain_autodiff(params):
    """The ultimate oracle: framework autodiff of the plain forward."""
    batch = _batch()
    _, g_plain = mesp.value_and_grad(params, CFG, batch, mode="plain")
    _, g_p = mesp.value_and_grad(params, CFG, batch, mode="pallas")
    assert _rel(g_p, g_plain) <= 1e-5


def test_pallas_train_step_runs_and_descends(params):
    batch = _batch()
    p, l0 = mesp.train_step(params, CFG, batch, 5e-2, mode="pallas")
    for _ in range(3):
        p, l = mesp.train_step(p, CFG, batch, 5e-2, mode="pallas")
    assert float(l) < float(l0)


def test_pallas_step_equals_structured_step(params):
    """One SGD step in each mode must land on the same parameters."""
    batch = _batch()
    p_s, _ = mesp.train_step(params, CFG, batch, 1e-2, mode="structured")
    p_p, _ = mesp.train_step(params, CFG, batch, 1e-2, mode="pallas")
    for a, b in zip(jax.tree_util.tree_leaves(p_p),
                    jax.tree_util.tree_leaves(p_s)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


def test_pallas_grads_match_structured_quantized():
    """``quantize=int8`` composes with mode="pallas": the dequant-in-VMEM
    kernels and the structured dequant fallback agree ≤1e-5 on the same
    non-tile-aligned shapes (full suite in test_quant_mode.py)."""
    qp = M.init_params(jax.random.PRNGKey(0), CFG, quantize="int8")
    batch = _batch()
    l_s, g_s = mesp.value_and_grad(qp, CFG, batch, mode="structured")
    l_p, g_p = mesp.value_and_grad(qp, CFG, batch, mode="pallas")
    np.testing.assert_allclose(float(l_p), float(l_s), rtol=1e-6)
    assert _rel(g_p, g_s) <= 1e-5


def test_fused_rope_grads_match_structured(params):
    """``fuse_rope=True`` moves the q/k rotation inside the flash kernels
    (cos/sin tables streamed per tile, dq/dk counter-rotated): gradients
    must stay ≤1e-5 of the structured path's jnp RoPE on the same
    non-tile-aligned shapes."""
    from repro.api import ExecutionPolicy
    batch = _batch()
    l_s, g_s = mesp.value_and_grad(params, CFG, batch, mode="structured")
    l_f, g_f = mesp.value_and_grad(
        params, CFG, batch,
        policy=ExecutionPolicy(backend="pallas", fuse_rope=True))
    np.testing.assert_allclose(float(l_f), float(l_s), rtol=1e-6)
    assert _rel(g_f, g_s) <= 1e-5


def test_fused_rope_matches_unfused_pallas(params):
    """fuse_rope only changes *where* the rotation happens, not the math:
    pallas-with-fused-rope ≡ pallas-with-jnp-rope bit-closely."""
    from repro.api import ExecutionPolicy
    batch = _batch()
    _, g_p = mesp.value_and_grad(
        params, CFG, batch, policy=ExecutionPolicy(backend="pallas"))
    _, g_f = mesp.value_and_grad(
        params, CFG, batch,
        policy=ExecutionPolicy(backend="pallas", fuse_rope=True))
    assert _rel(g_f, g_p) <= 1e-5


def test_rope_kernel_matches_jnp_rope():
    """Standalone fused RoPE kernel (kernels/rope.py) ≡ models/layers.rope,
    forward and gradient, on a non-aligned length."""
    from repro.kernels.rope import rope_apply, rope_tables
    from repro.models.layers import rope as jnp_rope
    B, N, H, D = 2, 200, 3, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, N, H, D)) * 0.5
    pos = jnp.arange(N)
    cos, sin = rope_tables(pos, 10000.0, D)
    y_k = rope_apply(x, cos, sin, True)
    y_j = jnp_rope(x, pos, 10000.0)
    np.testing.assert_allclose(y_k, y_j, rtol=1e-6, atol=1e-6)
    g_k = jax.grad(lambda x: jnp.sum(jnp.sin(rope_apply(x, cos, sin,
                                                        True))))(x)
    g_j = jax.grad(lambda x: jnp.sum(jnp.sin(jnp_rope(x, pos,
                                                      10000.0))))(x)
    np.testing.assert_allclose(g_k, g_j, rtol=1e-5, atol=1e-5)


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """A persisted cache named by REPRO_AUTOTUNE_CACHE is loaded on first
    use and consulted by choose_blocks before the heuristics."""
    import importlib
    import json
    from repro.kernels import autotune

    key = (f"flash|D=32/Nk=777/Nq=777/causal=1/window=0|float32|"
           f"{jax.default_backend()}")
    path = tmp_path / "measured.json"
    path.write_text(json.dumps({key: {"bq": 256, "bk": 128}}))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    importlib.reload(autotune)
    try:
        blk = autotune.choose_blocks("flash", jnp.float32, Nq=777, Nk=777,
                                     D=32, causal=1, window=0)
        assert blk == {"bq": 256, "bk": 128}
        # unrelated shapes still hit the heuristic table
        assert autotune.choose_blocks("flash", jnp.float32, Nq=128, Nk=128,
                                      D=32, causal=1, window=0)
    finally:
        monkeypatch.delenv("REPRO_AUTOTUNE_CACHE")
        importlib.reload(autotune)


def test_builtin_backend_cache_checked_in():
    """The per-backend-generation cache shipped in the repo loads on first
    use (CI runs on cpu; TPU generations get their own committed file).
    Loading is lazy so importing the package never initializes JAX."""
    import os
    from repro.kernels import autotune
    if not os.path.exists(autotune.builtin_cache_path()):
        pytest.skip(f"no checked-in cache for {autotune.backend_generation()}")
    autotune.choose_blocks("rmsnorm", jnp.float32, M=128, d=128)  # first use
    assert any(k.endswith(f"|{jax.default_backend()}")
               for k in autotune._CACHE)


def test_fused_rope_asymmetric_blocks():
    """bq != bk (a legal measured-cache outcome): the rope tables are read
    through both (bq, ·) and (bk, ·) blocks and must stay in bounds."""
    from repro.kernels import flash_attention as fa
    from repro.kernels.rope import apply_rope_tables, rope_tables
    N, D = 300, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (2, N, D)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (2, N, D)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (2, N, D)) * 0.5
    cos, sin = rope_tables(jnp.arange(N), 10000.0, D)
    for bq, bk in ((128, 256), (256, 128)):
        kw = dict(causal=True, window=0, bq=bq, bk=bk, interpret=True)
        o_f, l_f = fa.flash_attention_fwd(q, k, v, (cos, sin),
                                          return_lse=True, **kw)
        o_r = fa.flash_attention_fwd(apply_rope_tables(q, cos, sin),
                                     apply_rope_tables(k, cos, sin), v, **kw)
        np.testing.assert_allclose(o_f, o_r, rtol=2e-5, atol=2e-5)
        g = jax.random.normal(jax.random.PRNGKey(3), (2, N, D)) * 0.5
        fa.flash_attention_bwd(q, k, v, o_f, l_f, g, (cos, sin), **kw)


def test_dispatch_falls_back_on_unsupported():
    """MoE-style batched [E,·,·] weights take the structured path (and still
    deliver correct gradients through the dispatcher)."""
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    E, C, d, f, r = 2, 8, 16, 12, 4
    x = jax.random.normal(keys[0], (E, C, d))
    w0 = jax.random.normal(keys[1], (E, d, f)) * 0.1
    a = jax.random.normal(keys[2], (E, d, r)) * 0.3
    b = jax.random.normal(keys[3], (E, r, f)) * 0.3
    assert not ops.lora_supported(x, w0)
    f1 = lambda x, a, b: jnp.sum(jnp.tanh(ops.lora_linear(x, w0, a, b, None, 2.0)))
    f2 = lambda x, a, b: jnp.sum(jnp.tanh(x @ w0 + 2.0 * ((x @ a) @ b)))
    g1 = jax.grad(f1, (0, 1, 2))(x, a, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, a, b)
    for u, w in zip(g1, g2):
        np.testing.assert_allclose(u, w, rtol=2e-5, atol=2e-5)
