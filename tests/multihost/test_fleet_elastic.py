"""Emulated-fleet elasticity + collective-traffic checks.

Elastic contract (``Trainer.resize`` / ``runtime.elastic``), proven on a
real 8-device emulated fleet inside one worker subprocess:

* ``reshard_tree`` is placement-only — an 8→4→8 round trip of the state is
  bit-exact;
* a live mid-run resize (8→4→8 through the Trainer facade) is
  **bit-identical** to the checkpoint-save/restore-onto-the-resized-mesh
  path: both execute the same sequence of XLA programs, which is the
  invariant a preemption recovery actually relies on;
* vs the *uninterrupted* 8-device run the resized trajectory agrees to
  float tolerance only — XLA emits a different SPMD partitioning per device
  count, so cross-device-count bit-identity is unattainable by
  construction (measured and documented in docs/sharding.md).

Collective traffic: the payload bytes parsed from the compiled HLO
(``roofline.analysis.collective_bytes``) must dominate the analytic
gradient-sync prediction (``predicted_grad_sync_bytes``), and a
single-device program must contain no collectives at all.

Topology: ``make_mesh_from_devices`` pods>1 axis naming needs >= 4 real
devices, so it is probed here rather than in tests/test_elastic.py.
"""
import pytest

from repro.launch.fleet import run_fleet

BASE = {"reduced": True, "batch": 4, "seq": 32, "seed": 5}


def test_elastic_resize_8_4_8_trajectory():
    spec = dict(BASE, engine="mesp", optimizer="sgd_momentum",
                model_parallel=2)
    r = run_fleet({"task": "elastic", "spec": spec, "phases": [2, 2, 2],
                   "shrink_to": 4}, devices=8, timeout=1500)
    assert r["devices"] == 8 and r["shrink_to"] == 4
    assert r["reshard_bitexact"]
    assert r["b_vs_c_bitwise"], (r["losses_b"], r["losses_c"])
    assert r["b_vs_a_maxdiff"] <= 1e-6
    assert len(r["losses_b"]) == 6


def test_collective_bytes_dominate_roofline_prediction():
    spec = dict(BASE, engine="mesp", optimizer="sgd", model_parallel=2)
    r = run_fleet({"task": "collectives", "spec": spec}, devices=4)
    cb = r["collective_bytes"]
    assert r["mesh"] == {"data": 2, "model": 2}
    assert r["n_trainable"] > 0
    assert r["predicted_grad_sync_bytes"] > 0
    # the DP gradient sync is an all-reduce over the trainable elements;
    # the compiled program can only add traffic on top of that floor
    assert cb["all-reduce"] >= r["predicted_grad_sync_bytes"]
    # model parallelism must introduce activation/weight movement too
    assert cb["all-gather"] + cb["all-to-all"] + cb["collective-permute"] > 0


def test_dp_only_fleet_all_reduces_full_grads():
    spec = dict(BASE, engine="mesp", optimizer="sgd", model_parallel=1)
    r = run_fleet({"task": "collectives", "spec": spec}, devices=2)
    # mp=1: every device holds the full factors, so the static floor is one
    # layer slice of the stacked blocks' grads in the compute dtype (the
    # backward's block loop compiles to ONE body, run L times) — undivided
    assert r["predicted_grad_sync_bytes"] == r["static_trainable_bytes"]
    assert r["static_trainable_bytes"] < r["trainable_bytes"]
    assert r["collective_bytes"]["all-reduce"] >= \
        r["predicted_grad_sync_bytes"]


def test_single_device_program_has_no_collectives():
    spec = dict(BASE, engine="mesp", optimizer="sgd", model_parallel=1)
    r = run_fleet({"task": "collectives", "spec": spec}, devices=1)
    assert sum(r["collective_bytes"].values()) == 0
    assert r["predicted_grad_sync_bytes"] == 0


def test_degrade_ladder_runs_on_model_parallel_mesh():
    """Sharding × resilience seam: every buildable ladder rung reachable
    from a model-parallel spec compiles and takes a real sharded step —
    halved batch below the DP size, int8 {"q","scale"} leaves, truncated
    seq breaking Megatron-SP divisibility included."""
    spec = dict(BASE, engine="mesp_pallas", optimizer="sgd", batch=2,
                seq=64, model_parallel=2)
    r = run_fleet({"task": "ladder", "spec": spec}, devices=4, timeout=1500)
    assert r["mesh"] == {"data": 2, "model": 2}
    by_rung = {row["rung"]: row for row in r["rungs"]}
    assert {"halve_batch", "engine_mesp", "quantize_int8",
            "truncate_seq"} <= set(by_rung)
    for rung, row in by_rung.items():
        assert row["built"], (rung, row.get("reason"))
        assert row["finite"], (rung, row)
    # halve_batch lands at batch 1 < dp=2: replicated batch, still steps
    assert by_rung["halve_batch"]["batch"] == 1
    # truncate_seq lands at 32, not divisible by... 32 % 2 == 0: still SP;
    # the int8 rung keeps the quantized leaves sharded (placement checked
    # in tests/test_fleet_harness.py, execution here)
    assert by_rung["quantize_int8"]["quantize"] == "int8"


@pytest.mark.parametrize("pods,mp,expect_axes,expect_shape", [
    (1, 2, ["data", "model"], {"data": 4, "model": 2}),
    (2, 2, ["pod", "data", "model"], {"pod": 2, "data": 2, "model": 2}),
])
def test_make_mesh_pods_axis_naming(pods, mp, expect_axes, expect_shape):
    r = run_fleet({"task": "probe", "model_parallel": mp, "pods": pods},
                  devices=8)
    assert r["axis_names"] == expect_axes
    assert r["mesh"] == expect_shape
