"""Emulated-fleet correctness: multi-device MeSP equivalence.

Every test here spawns a fresh subprocess via ``launch/fleet.py`` with
``--xla_force_host_platform_device_count=N`` in its environment — the flag
must be set before JAX initializes, and this pytest process initialized JAX
long ago, so emulated fleets can never run in-process.

The contract under test (ISSUE/ROADMAP "fleet-scale proof"):

* sharded train steps through ``Trainer.from_spec`` on (data, model) meshes
  of 2/4/8 emulated devices produce the same losses and final state as the
  single-device run, to <= 1e-6 — for the mesp, mesp_pallas and mesp_seq
  engines and for int8-quantized frozen weights;
* one XLA SPMD program per device count: *bit*-identity across device
  counts is not promised (docs/sharding.md), placement changes are.

Single-device references are cached per spec across parametrized cases.
"""
import functools
import json
import os
import tempfile

import numpy as np
import pytest

from repro.launch.fleet import run_fleet

BASE = {"reduced": True, "batch": 4, "seq": 32, "seed": 3, "steps": 3}
STEPS = 3
#: the model computes in bf16 with f32 accumulations; resharding changes
#: reduction orders, so equivalence is atol+rtol 1e-6 (loss is O(5), params
#: are O(0.1) — both land comfortably inside this band, while any real
#: sharding bug is orders of magnitude outside it)
ATOL = 1e-6
RTOL = 1e-6


def _j(spec: dict) -> str:
    return json.dumps(spec, sort_keys=True)


@functools.lru_cache(maxsize=None)
def _train(spec_json: str, devices: int):
    """(result, {leaf-path: ndarray}) for a fleet train run — cached so the
    shared single-device references run once per spec."""
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "state.npz")
        res = run_fleet({"task": "train", "spec": json.loads(spec_json),
                         "steps": STEPS, "out": out}, devices=devices)
        with np.load(out) as data:
            state = {k: data[k].copy() for k in data.files}
    return res, state


# (engine, quantize, optimizer, devices, model_parallel) — meshes of 2, 4
# and 8 devices; dp-only, mp-only and mixed splits all appear
CASES = [
    ("mesp",        "none", "sgd_momentum", 2, 1),   # dp=2
    ("mesp",        "none", "sgd_momentum", 4, 2),   # dp=2 x mp=2
    ("mesp",        "none", "sgd_momentum", 8, 2),   # dp=4 x mp=2
    ("mesp",        "none", "sgd_momentum", 2, 2),   # mp-only
    ("mesp_pallas", "none", "sgd_momentum", 4, 2),
    ("mesp_seq",    "none", "sgd",          2, 1),   # seq engine is SGD-only
    ("mesp",        "int8", "sgd_momentum", 4, 2),
]


@pytest.mark.parametrize("engine,quantize,optimizer,devices,mp", CASES)
def test_sharded_matches_single_device(engine, quantize, optimizer,
                                       devices, mp):
    spec = dict(BASE, engine=engine, quantize=quantize, optimizer=optimizer)
    ref, ref_state = _train(_j(dict(spec, model_parallel=1)), 1)
    res, state = _train(_j(dict(spec, model_parallel=mp)), devices)

    assert ref["devices"] == 1 and ref["mesh"] == {}
    assert res["devices"] == devices
    assert res["mesh"].get("model", 1) == mp
    assert res["mesh"]["data"] * mp == devices

    np.testing.assert_allclose(res["losses"], ref["losses"],
                               atol=ATOL, rtol=RTOL)
    assert set(state) == set(ref_state)
    for k in sorted(ref_state):
        np.testing.assert_allclose(state[k], ref_state[k], atol=ATOL,
                                   rtol=RTOL, err_msg=k)


def test_losses_actually_train():
    # guard against the degenerate "everything matches because nothing
    # happens" failure mode: the loss must move over the run
    _, spec_json = None, _j(dict(BASE, engine="mesp", quantize="none",
                                 optimizer="sgd_momentum", model_parallel=1))
    ref, _state = _train(spec_json, 1)
    assert len(set(ref["losses"])) > 1
