"""Quickstart: MeSP LoRA fine-tuning in ~50 lines.

Builds a reduced Qwen2.5-family model, verifies the paper's structured
gradients match framework autodiff exactly — and that the int8-quantized
pallas kernel path matches its dequant oracle — then fine-tunes the LoRA
adapters.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mebp, mesp
from repro.data import make_batch_iterator
from repro.models import model as M


def main():
    # 1. a model config (any of the 13 registered archs; .reduced() for CPU)
    cfg = get_config("qwen2.5-0.5b").reduced()
    print(f"model: {cfg.name} (reduced) — {cfg.n_layers}L d={cfg.d_model} "
          f"LoRA r={cfg.lora.rank} on {cfg.lora.targets}")

    # 2. params (frozen base + LoRA A/B) and a data stream
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = make_batch_iterator(cfg.vocab, seq_len=64, global_batch=4)

    # 3. sanity: MeSP's hand-derived gradients == autodiff gradients
    batch = next(data)
    _, g_mesp = mesp.value_and_grad(params, cfg, batch)
    _, g_mebp = mebp.value_and_grad(params, cfg, batch)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(g_mesp), jax.tree_util.tree_leaves(g_mebp)))
    print(f"max |MeSP_grad − autodiff_grad| = {err:.2e}  (paper §5.5)")

    # 3b. quantized base weights (--quantize int8): the dequant-in-VMEM
    # kernel path agrees with the structured path on the same int8 W0
    qparams = M.init_params(jax.random.PRNGKey(0), cfg, quantize="int8")
    _, g_q = mesp.value_and_grad(qparams, cfg, batch, mode="pallas")
    _, g_qs = mesp.value_and_grad(qparams, cfg, batch, mode="structured")
    flat = lambda t: jnp.concatenate([x.reshape(-1) for x in
                                      jax.tree_util.tree_leaves(t)])
    rel = float(jnp.linalg.norm(flat(g_q) - flat(g_qs)) /
                jnp.linalg.norm(flat(g_qs)))
    print(f"int8 W0: pallas-kernel vs structured grad rel err = {rel:.2e}")
    assert rel <= 1e-5, "quantized kernel path diverged from structured"

    # 4. fine-tune
    step = jax.jit(lambda p, b: mesp.train_step(p, cfg, b, lr=5e-2))
    for i in range(50):
        params, loss = step(params, next(data))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")
    print(f"final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
