"""Quickstart: MeSP LoRA fine-tuning in ~40 lines.

Builds a reduced Qwen2.5-family model, fine-tunes LoRA adapters with the
paper's structured backward, and verifies the gradients match framework
autodiff exactly.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mebp, mesp
from repro.data import make_batch_iterator
from repro.models import model as M


def main():
    # 1. a model config (any of the 13 registered archs; .reduced() for CPU)
    cfg = get_config("qwen2.5-0.5b").reduced()
    print(f"model: {cfg.name} (reduced) — {cfg.n_layers}L d={cfg.d_model} "
          f"LoRA r={cfg.lora.rank} on {cfg.lora.targets}")

    # 2. params (frozen base + LoRA A/B) and a data stream
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = make_batch_iterator(cfg.vocab, seq_len=64, global_batch=4)

    # 3. sanity: MeSP's hand-derived gradients == autodiff gradients
    batch = next(data)
    _, g_mesp = mesp.value_and_grad(params, cfg, batch)
    _, g_mebp = mebp.value_and_grad(params, cfg, batch)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(g_mesp), jax.tree_util.tree_leaves(g_mebp)))
    print(f"max |MeSP_grad − autodiff_grad| = {err:.2e}  (paper §5.5)")

    # 4. fine-tune
    step = jax.jit(lambda p, b: mesp.train_step(p, cfg, b, lr=5e-2))
    for i in range(50):
        params, loss = step(params, next(data))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")
    print(f"final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
