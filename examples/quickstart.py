"""Quickstart: MeSP LoRA fine-tuning in ~50 lines, via ``repro.api``.

Builds a reduced Qwen2.5-family model, verifies the paper's structured
gradients match framework autodiff exactly — and that the quantized pallas
kernel path matches its dequant oracle — then fine-tunes the LoRA adapters
through the Trainer facade.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --quantize nf4

``--quantize`` picks the frozen-W0 format for the sanity check *and* the
fine-tune (any ``core.quant.METHODS`` entry: int8 dequant-in-VMEM, or the
packed int4/nf4 nibble-unpack kernels from ``kernels/lora_pack4.py``).
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.api import ExecutionPolicy, Trainer, TrainSpec, get_engine
from repro.configs import get_config
from repro.models import model as M


def main(argv=None):
    from repro.core import quant

    ap = argparse.ArgumentParser()
    ap.add_argument("--quantize", default="int8",
                    choices=[m for m in quant.METHODS if m != "none"],
                    help="frozen-W0 format for the quantized sanity check "
                         "and the fine-tune (default: int8)")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="write structured telemetry (events.jsonl + "
                         "trace.json) for the fine-tune into DIR")
    args = ap.parse_args(argv)

    # 1. a model config (any of the 13 registered archs; .reduced() for CPU)
    cfg = get_config("qwen2.5-0.5b").reduced()
    print(f"model: {cfg.name} (reduced) — {cfg.n_layers}L d={cfg.d_model} "
          f"LoRA r={cfg.lora.rank} on {cfg.lora.targets}")

    # 2. params (frozen base + LoRA A/B) and a probe batch
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    # 3. sanity: MeSP's hand-derived gradients == autodiff gradients.
    #    Engines come from the registry; the ExecutionPolicy selects the
    #    backward regime each one threads through the model stack.
    mesp, mebp = get_engine("mesp"), get_engine("mebp")
    _, g_mesp = mesp.value_and_grad(params, cfg, batch,
                                    policy=ExecutionPolicy())
    _, g_mebp = mebp.value_and_grad(params, cfg, batch,
                                    policy=ExecutionPolicy(backend="plain"))
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(g_mesp), jax.tree_util.tree_leaves(g_mebp)))
    print(f"max |MeSP_grad − autodiff_grad| = {err:.2e}  (paper §5.5)")

    # 3b. quantized base weights: the quantized kernel path (int8
    # dequant-in-VMEM, or int4/nf4 in-kernel nibble unpack) agrees with the
    # structured path on the same quantized W0
    qparams = M.init_params(jax.random.PRNGKey(0), cfg,
                            quantize=args.quantize)
    _, g_q = mesp.value_and_grad(qparams, cfg, batch,
                                 policy=ExecutionPolicy(backend="pallas"))
    _, g_qs = mesp.value_and_grad(qparams, cfg, batch,
                                  policy=ExecutionPolicy())
    flat = lambda t: jnp.concatenate([x.reshape(-1) for x in
                                      jax.tree_util.tree_leaves(t)])
    rel = float(jnp.linalg.norm(flat(g_q) - flat(g_qs)) /
                jnp.linalg.norm(flat(g_qs)))
    print(f"{args.quantize} W0: pallas-kernel vs structured grad "
          f"rel err = {rel:.2e}")
    assert rel <= 1e-5, "quantized kernel path diverged from structured"

    # 4. fine-tune: one declarative spec, one facade call (quantized W0 —
    # only the LoRA factors train, so the frozen format just shrinks HBM)
    spec = TrainSpec(arch="qwen2.5-0.5b", reduced=True, engine="mesp",
                     quantize=args.quantize,
                     lr=5e-2, steps=50, seq=64, batch=4,
                     ckpt_dir=tempfile.mkdtemp(prefix="repro_quickstart_"),
                     telemetry="on" if args.telemetry else "off",
                     telemetry_dir=args.telemetry or "")
    result = Trainer.from_spec(spec).fit(
        on_step=lambda r: r.step % 10 == 0 and print(
            f"step {r.step:3d}  loss {r.loss:.4f}"))
    print(f"final loss {result.final_loss:.4f}")
    if args.telemetry:
        wm = result.metrics.get("watermark", {})
        print(f"telemetry: {result.metrics.get('events_by_kind')} -> "
              f"{args.telemetry} (peak {wm.get('measured_peak_mb')} MB "
              f"measured vs {wm.get('predicted_peak_mb')} MB predicted, "
              f"source={wm.get('source')})")


if __name__ == "__main__":
    main()
