"""End-to-end driver: train a ~100M-param dense model for a few hundred
steps with the FULL production substrate — a declarative TrainSpec run
through the ``repro.api.Trainer`` facade (engine registry, SGD,
checkpointing with auto-resume, restartable data pipeline, straggler
watchdog) — then evaluate and greedy-decode from the fine-tuned model.

    PYTHONPATH=src python examples/finetune_e2e.py [--steps 300]

(~100M params: 12L × d_model 768 × vocab 32k runs on this CPU at a few
steps/sec; pass --tiny for a smoke-scale run.)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.api import Trainer, TrainSpec
from repro.configs import get_config
from repro.configs.base import LoRAConfig
from repro.models import model as M
from repro.runtime.fault_tolerance import StragglerPolicy


def build_cfg(tiny: bool):
    base = get_config("qwen2.5-0.5b")
    if tiny:
        return base.reduced()
    return dataclasses.replace(
        base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32768, dtype="float32",
        lora=LoRAConfig(rank=8, alpha=16.0))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e")
    args = ap.parse_args()

    cfg = build_cfg(args.tiny)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} "
          f"≈ {cfg.n_params()/1e6:.0f}M params")

    # a custom ArchConfig overrides the spec's arch/reduced resolution
    spec = TrainSpec(engine="mesp", optimizer="sgd", lr=5e-2,
                     steps=args.steps, seq=args.seq, batch=args.batch,
                     seed=11, ckpt_dir=args.ckpt_dir, ckpt_interval=100,
                     log_interval=25)
    trainer = Trainer.from_spec(spec, cfg=cfg)

    t0 = time.monotonic()
    losses = []

    def on_step(res):
        losses.append(res.loss)
        if res.step % 25 == 0:
            print(f"step {res.step:4d}  loss {res.loss:.4f}  "
                  f"{res.seconds:.2f}s/step")

    result = trainer.fit(on_step=on_step,
                         straggler=StragglerPolicy(factor=20.0))
    dt = time.monotonic() - t0
    if losses:
        tail = losses[-10:]
        print(f"\ntrained {len(result.history)} steps in {dt:.0f}s; "
              f"loss {losses[0]:.3f} → {sum(tail)/len(tail):.3f}")
    else:  # checkpoint already covered all steps (resumed, nothing to do)
        print(f"\nnothing to train: checkpoint in {args.ckpt_dir} already "
              f"at step {args.steps}")

    # --- serve from the fine-tuned params -----------------------------------
    params = result.params
    cache = M.init_cache(cfg, 1, 32)
    tok = jnp.array([[1]], jnp.int32)
    out = []
    dstep = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))
    for _ in range(16):
        logits, cache = dstep(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode:", out)


if __name__ == "__main__":
    main()
