"""End-to-end driver: train a ~100M-param dense model for a few hundred
steps with the FULL production substrate — MeSP engine, SGD, checkpointing
with auto-resume, restartable data pipeline, straggler watchdog — then
evaluate and greedy-decode from the fine-tuned model.

    PYTHONPATH=src python examples/finetune_e2e.py [--steps 300]

(~100M params: 12L × d_model 768 × vocab 32k runs on this CPU at a few
steps/sec; pass --tiny for a smoke-scale run.)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.configs.base import LoRAConfig
from repro.core import mesp
from repro.data import make_batch_iterator
from repro.models import model as M
from repro.optim import sgd
from repro.runtime.fault_tolerance import StragglerPolicy, run_resilient


def build_cfg(tiny: bool):
    base = get_config("qwen2.5-0.5b")
    if tiny:
        return base.reduced()
    return dataclasses.replace(
        base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32768, dtype="float32",
        lora=LoRAConfig(rank=8, alpha=16.0))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e")
    args = ap.parse_args()

    cfg = build_cfg(args.tiny)
    n_params = cfg.n_params()
    print(f"model: {cfg.n_layers}L d={cfg.d_model} ≈ {n_params/1e6:.0f}M params")

    opt = sgd(5e-2)

    def step(params, opt_state, batch):
        loss, grads = mesp.value_and_grad(params, cfg, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    step = jax.jit(step)
    data = make_batch_iterator(cfg.vocab, args.seq, args.batch,
                               n_tokens=1 << 18, seed=11)
    ckpt = Checkpointer(args.ckpt_dir, interval=100)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return params, opt.init(params)

    t0 = time.monotonic()
    losses = []

    def on_step(res):
        losses.append(res.loss)
        if res.step % 25 == 0:
            print(f"step {res.step:4d}  loss {res.loss:.4f}  "
                  f"{res.seconds:.2f}s/step")

    params, opt_state, results = run_resilient(
        step, init_state, data, ckpt, args.steps,
        straggler=StragglerPolicy(factor=20.0), on_step=on_step)
    dt = time.monotonic() - t0
    print(f"\ntrained {len(results)} steps in {dt:.0f}s; "
          f"loss {losses[0]:.3f} → {sum(losses[-10:])/10:.3f}")

    # --- serve from the fine-tuned params -----------------------------------
    cache = M.init_cache(cfg, 1, 32)
    tok = jnp.array([[1]], jnp.int32)
    out = []
    dstep = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))
    for _ in range(16):
        logits, cache = dstep(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode:", out)


if __name__ == "__main__":
    main()
