"""Reproduce the paper's §5.6 analysis: why does MeZO converge slowly?

Computes MeZO's SPSA gradient estimate and the exact (MeSP) gradient on the
same batch and reports per-layer cosine similarity / sign agreement /
relative error (paper Table 3), plus the variance scaling with parameter
count (paper §3.2).

    PYTHONPATH=src python examples/gradient_quality.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import gradcheck, mesp, mezo
from repro.models import model as M


def main():
    cfg = dataclasses.replace(get_config("qwen2.5-0.5b").reduced(),
                              n_layers=8)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    for _ in range(5):  # warm up so LoRA B ≠ 0
        params, _ = mesp.train_step(params, cfg, batch, 5e-2)

    _, g_true = mesp.value_and_grad(params, cfg, batch)
    _, g_est = mezo.spsa_grad(params, cfg, batch, jax.random.PRNGKey(2))

    print("layer | cosine sim | sign agree | rel. error   (paper Table 3)")
    rows = gradcheck.per_layer_metrics(g_est["blocks"], g_true["blocks"],
                                       cfg.n_layers)
    for r in rows:
        print(f"{r['layer']:5d} | {r['cosine_sim']:+.4f}    | "
              f"{r['sign_agree']:.1%}      | {r['rel_error']:.1f}")
    avg = gradcheck.gradient_metrics(g_est, g_true)
    print(f"  all | {float(avg['cosine_sim']):+.4f}    | "
          f"{float(avg['sign_agree']):.1%}      | "
          f"{float(avg['rel_error']):.1f}")

    # variance scaling: averaging K estimates improves cosine ~ sqrt(K)
    print("\nSPSA estimates averaged | cosine vs true")
    acc = None
    for k in range(1, 33):
        _, g = mezo.spsa_grad(params, cfg, batch, jax.random.PRNGKey(100 + k))
        acc = g if acc is None else jax.tree_util.tree_map(jnp.add, acc, g)
        if k in (1, 4, 16, 32):
            m = gradcheck.gradient_metrics(
                jax.tree_util.tree_map(lambda x: x / k, acc), g_true)
            print(f"{k:23d} | {float(m['cosine_sim']):+.4f}")
    print("\n→ single-sample MeZO directions are ≈ uncorrelated with the true "
          "gradient (paper's explanation for its slow convergence).")


if __name__ == "__main__":
    main()
