"""Reproduce the paper's §5.6 analysis: why does MeZO converge slowly?

Computes MeZO's SPSA gradient estimate and the exact (MeSP) gradient on the
same batch — both through the engine registry's ``value_and_grad`` hooks —
and reports per-layer cosine similarity / sign agreement / relative error
(paper Table 3), plus the variance scaling with parameter count (§3.2).

    PYTHONPATH=src python examples/gradient_quality.py [--smoke]

``--smoke`` runs a scaled-down version (fewer layers / averaging samples)
and finishes with a 3-step fine-tune through the ``repro.api.Trainer``
facade — the CI gate for the declarative API path.
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecutionPolicy, Trainer, TrainSpec, get_engine
from repro.configs import get_config
from repro.core import gradcheck, mesp
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down run + Trainer-facade smoke fit (CI)")
    args = ap.parse_args(argv)

    n_layers = 4 if args.smoke else 8
    cfg = dataclasses.replace(get_config("qwen2.5-0.5b").reduced(),
                              n_layers=n_layers)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    for _ in range(5):  # warm up so LoRA B ≠ 0
        params, _ = mesp.train_step(params, cfg, batch, 5e-2)

    mesp_eng, mezo_eng = get_engine("mesp"), get_engine("mezo")
    policy = ExecutionPolicy()
    _, g_true = mesp_eng.value_and_grad(params, cfg, batch, policy=policy)
    _, g_est = mezo_eng.value_and_grad(params, cfg, batch, policy=policy,
                                       key=jax.random.PRNGKey(2))

    print("layer | cosine sim | sign agree | rel. error   (paper Table 3)")
    rows = gradcheck.per_layer_metrics(g_est["blocks"], g_true["blocks"],
                                       cfg.n_layers)
    for r in rows:
        print(f"{r['layer']:5d} | {r['cosine_sim']:+.4f}    | "
              f"{r['sign_agree']:.1%}      | {r['rel_error']:.1f}")
    avg = gradcheck.gradient_metrics(g_est, g_true)
    print(f"  all | {float(avg['cosine_sim']):+.4f}    | "
          f"{float(avg['sign_agree']):.1%}      | "
          f"{float(avg['rel_error']):.1f}")

    # variance scaling: averaging K estimates improves cosine ~ sqrt(K)
    print("\nSPSA estimates averaged | cosine vs true")
    acc = None
    k_max = 4 if args.smoke else 32
    marks = (1, 4) if args.smoke else (1, 4, 16, 32)
    for k in range(1, k_max + 1):
        _, g = mezo_eng.value_and_grad(params, cfg, batch, policy=policy,
                                       key=jax.random.PRNGKey(100 + k))
        acc = g if acc is None else jax.tree_util.tree_map(jnp.add, acc, g)
        if k in marks:
            m = gradcheck.gradient_metrics(
                jax.tree_util.tree_map(lambda x: x / k, acc), g_true)
            print(f"{k:23d} | {float(m['cosine_sim']):+.4f}")
    print("\n→ single-sample MeZO directions are ≈ uncorrelated with the true "
          "gradient (paper's explanation for its slow convergence).")

    if args.smoke:
        # exercise the declarative path end-to-end: spec → Trainer → fit
        spec = TrainSpec(arch="qwen2.5-0.5b", reduced=True, engine="mesp",
                         lr=5e-2, steps=3, seq=32, batch=2,
                         ckpt_dir=tempfile.mkdtemp(prefix="repro_gq_smoke_"))
        result = Trainer.from_spec(spec).fit()
        assert np.isfinite(result.final_loss)
        print(f"\nTrainer smoke fit: {len(result.history)} steps, "
              f"final loss {result.final_loss:.4f}")


if __name__ == "__main__":
    main()
