"""CI guard: README.md must stay honest against the code.

Two checks:

1. every ``--flag`` README.md attributes to the training launcher must
   actually be exposed by ``repro.launch.train``'s argument parser (which is
   generated from ``repro.api``);
2. the "Engines × quantization" matrix must agree with the engine registry:
   same engine set, same declared backend and ``--quantize`` support per
   engine — so registering/changing an engine forces the docs to follow.

Exits non-zero (failing CI) on any mismatch.

    PYTHONPATH=src python scripts/check_readme_flags.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")
TICK_RE = re.compile(r"`([^`]+)`")


def readme_train_flags(text: str) -> set[str]:
    flags: set[str] = set()
    # fenced code blocks that invoke the launcher
    for block in re.findall(r"```.*?```", text, re.S):
        if "repro.launch.train" in block:
            flags.update(FLAG_RE.findall(block))
    # prose: lines in the paragraph(s) that enumerate launcher flags
    for para in text.split("\n\n"):
        if para.lstrip().startswith("Flags:"):
            flags.update(FLAG_RE.findall(para))
    return flags


def readme_engine_matrix(text: str) -> dict[str, dict]:
    """Parse the "## Engines × quantization" table into
    {engine: {"backend": str|None, "quantize": set[str]}}.

    Row convention: first cell = backticked engine name, second cell =
    backticked backend (or — for engines with a custom regime), last cell =
    backticked supported ``--quantize`` methods.
    """
    m = re.search(r"^## Engines × quantization$(.*?)(?=^## |\Z)", text,
                  re.S | re.M)
    if not m:
        return {}
    rows: dict[str, dict] = {}
    for line in m.group(1).splitlines():
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) < 3 or not cells[0].startswith("`"):
            continue  # prose, header, separator
        name = TICK_RE.findall(cells[0])[0]
        backend = (TICK_RE.findall(cells[1]) or [None])[0]
        rows[name] = {"backend": backend,
                      "quantize": set(TICK_RE.findall(cells[-1]))}
    return rows


def check_flags(text: str) -> list[str]:
    from repro.launch.train import build_arg_parser
    known = {opt for action in build_arg_parser()._actions
             for opt in action.option_strings if opt.startswith("--")}
    used = readme_train_flags(text)
    if not used:
        return ["README.md documents no repro.launch.train flags "
                "(quickstart section missing?)"]
    unknown = sorted(used - known)
    if unknown:
        return [f"README.md references launcher flags not exposed by "
                f"`python -m repro.launch.train --help`: {unknown} "
                f"(parser knows: {sorted(known)})"]
    print(f"OK: {len(used)} README launcher flags all exposed by the parser "
          f"({len(known)} known)")
    return []


def check_engine_matrix(text: str) -> list[str]:
    from repro.api import list_engines
    doc = readme_engine_matrix(text)
    if not doc:
        return ["README.md has no '## Engines × quantization' matrix"]
    errors = []
    registered = {e.name: e for e in list_engines()}
    missing = sorted(set(registered) - set(doc))
    stale = sorted(set(doc) - set(registered))
    if missing:
        errors.append(f"README engine matrix is missing registered "
                      f"engines: {missing}")
    if stale:
        errors.append(f"README engine matrix lists unregistered engines: "
                      f"{stale}")
    for name in sorted(set(doc) & set(registered)):
        eng, row = registered[name], doc[name]
        if row["backend"] != eng.backend:
            errors.append(f"engine {name!r}: README backend "
                          f"{row['backend']!r} != registry {eng.backend!r}")
        if row["quantize"] != set(eng.quantize):
            errors.append(f"engine {name!r}: README quantize "
                          f"{sorted(row['quantize'])} != registry "
                          f"{sorted(eng.quantize)}")
    if not errors:
        print(f"OK: README engine matrix matches the registry "
              f"({len(registered)} engines)")
    return errors


def main() -> int:
    readme = Path(__file__).resolve().parent.parent / "README.md"
    if not readme.exists():
        print(f"FAIL: {readme} does not exist")
        return 1
    text = readme.read_text()
    errors = check_flags(text) + check_engine_matrix(text)
    for e in errors:
        print(f"FAIL: {e}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
