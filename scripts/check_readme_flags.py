"""CI guard: every ``--flag`` README.md attributes to the training launcher
must actually be exposed by ``repro.launch.train``'s argument parser.

Scans fenced code blocks that invoke ``repro.launch.train`` and any prose
line mentioning the launcher/"Flags", extracts ``--long-option`` tokens and
diffs them against ``build_arg_parser()``. Exits non-zero (failing CI) on a
README flag the parser doesn't know.

    PYTHONPATH=src python scripts/check_readme_flags.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")


def readme_train_flags(text: str) -> set[str]:
    flags: set[str] = set()
    # fenced code blocks that invoke the launcher
    for block in re.findall(r"```.*?```", text, re.S):
        if "repro.launch.train" in block:
            flags.update(FLAG_RE.findall(block))
    # prose: lines in the paragraph(s) that enumerate launcher flags
    for para in text.split("\n\n"):
        if para.lstrip().startswith("Flags:"):
            flags.update(FLAG_RE.findall(para))
    return flags


def main() -> int:
    readme = Path(__file__).resolve().parent.parent / "README.md"
    if not readme.exists():
        print(f"FAIL: {readme} does not exist")
        return 1
    from repro.launch.train import build_arg_parser
    known = {opt for action in build_arg_parser()._actions
             for opt in action.option_strings if opt.startswith("--")}
    used = readme_train_flags(readme.read_text())
    if not used:
        print("FAIL: README.md documents no repro.launch.train flags "
              "(quickstart section missing?)")
        return 1
    unknown = sorted(used - known)
    if unknown:
        print(f"FAIL: README.md references launcher flags not exposed by "
              f"`python -m repro.launch.train --help`: {unknown}")
        print(f"      parser knows: {sorted(known)}")
        return 1
    print(f"OK: {len(used)} README launcher flags all exposed by the parser "
          f"({len(known)} known)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
