"""Render, validate, and benchmark telemetry run directories.

Three modes:

* **report** (default) — load every ``*.jsonl`` under a run directory
  (``--run DIR``), print the event-kind counts, step-loss trajectory,
  checkpoint/fault/degrade timeline, and span totals from ``trace.json``
  when present.

* **validate** (``--validate``) — schema-check every record
  (``repro.telemetry.events.validate_record``): envelope version, required
  per-kind fields, no unknown fields. ``--expect-kinds step,fault`` adds a
  hard coverage check that each named kind appears at least once (the
  chaos-smoke CI job uses this to assert faults/degradations/guard
  rejections actually landed in the timeline). Exit 1 on any problem.

* **sweep** (``--sweep``) — run tiny reduced fits across engine × quantize
  with ``--telemetry on`` and write ``BENCH_telemetry.json`` rows of
  *measured* peak memory (``repro.telemetry.memwatch``) vs the memsim
  *predicted* peak for the same live spec, plus step timings and event
  counts. ``scripts/check_bench_regression.py --telemetry`` gates schema
  version and row coverage against the committed baseline; the
  measured/predicted ratio itself is annotate-only on CPU, where
  ``memory_stats()`` is unavailable and the ``live_arrays`` fallback is a
  lower bound (in-jit temporaries are invisible).

    PYTHONPATH=src python scripts/telemetry_report.py --run /tmp/tele
    PYTHONPATH=src python scripts/telemetry_report.py --run /tmp/tele \\
        --validate --expect-kinds run,step,watermark
    PYTHONPATH=src python scripts/telemetry_report.py --sweep \\
        --out benchmarks/results/BENCH_telemetry.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.telemetry import events as ev  # noqa: E402

RESULTS_DIR = (Path(__file__).resolve().parent.parent / "benchmarks" /
               "results")
DEFAULT_OUT = str(RESULTS_DIR / "BENCH_telemetry.json")

#: engine × quantize grid for --sweep (every row reduced-config; small
#: enough for the CI smoke job, wide enough to cover a recomputation
#: engine, a baseline-BP engine, and the packed-int4 weight path)
SWEEP_ENGINES = ("mesp", "mebp")
SWEEP_QUANTIZE = ("none", "int8", "int4")
SWEEP_STEPS = 3


# --------------------------------------------------------------------- load
def load_run(run_dir: str) -> list[dict]:
    """All JSONL records under ``run_dir`` (single-run ``events.jsonl``,
    fleet ``worker_*.jsonl`` shards, or a merged ``fleet.jsonl``)."""
    records: list[dict] = []
    for path in sorted(glob.glob(os.path.join(run_dir, "*.jsonl"))):
        records.extend(ev.read_jsonl(path))
    records.sort(key=lambda r: (r.get("ts", 0.0), str(r.get("worker", "")),
                                r.get("seq", 0)))
    return records


def validate(records: list[dict],
             expect_kinds: list[str] | None = None) -> list[str]:
    """Schema errors (and kind-coverage gaps) across a record list."""
    errors: list[str] = []
    for i, rec in enumerate(records):
        for problem in ev.validate_record(rec):
            errors.append(f"record {i}: {problem}")
    seen = {r.get("kind") for r in records}
    for kind in expect_kinds or []:
        if kind not in seen:
            errors.append(f"expected kind {kind!r} absent from the timeline "
                          f"(present: {sorted(k for k in seen if k)})")
    return errors


# ------------------------------------------------------------------- report
def summarize(records: list[dict], run_dir: str) -> dict:
    by_kind: dict[str, int] = {}
    for r in records:
        by_kind[r.get("kind", "?")] = by_kind.get(r.get("kind", "?"), 0) + 1
    steps = [r for r in records if r.get("kind") == "step"]
    out: dict = {"records": len(records), "by_kind": by_kind}
    if steps:
        secs = sorted(r["seconds"] for r in steps)
        out["steps"] = {"count": len(steps),
                        "first_loss": steps[0]["loss"],
                        "final_loss": steps[-1]["loss"],
                        "median_step_s": secs[len(secs) // 2]}
    marks = [r for r in records if r.get("kind") == "watermark"]
    if marks:
        out["watermark"] = {"peak_mb": max(r["peak_mb"] for r in marks),
                            "source": marks[-1].get("source", "")}
    timeline = [r for r in records if r.get("kind") in
                ("fault", "degrade", "guard", "checkpoint")]
    if timeline:
        out["incidents"] = [
            {k: r[k] for k in ("kind", "step") if k in r} |
            {k: r[k] for k in ("fault", "rung", "reason", "action")
             if r.get(k)}
            for r in timeline]
    trace = os.path.join(run_dir, "trace.json")
    if os.path.exists(trace):
        with open(trace) as f:
            spans = json.load(f).get("traceEvents", [])
        totals: dict[str, dict] = {}
        for s in spans:
            t = totals.setdefault(s["name"], {"count": 0, "total_s": 0.0})
            t["count"] += 1
            t["total_s"] += s["dur"] / 1e6
        out["spans"] = {k: {"count": v["count"],
                            "total_s": round(v["total_s"], 4)}
                        for k, v in sorted(totals.items())}
    return out


# -------------------------------------------------------------------- sweep
def sweep_row(engine: str, quantize: str, steps: int, workdir: str) -> dict:
    """One tiny telemetry-on fit; measured vs predicted peak for the row."""
    from repro.api import TrainSpec, Trainer

    tdir = os.path.join(workdir, f"{engine}_{quantize}")
    spec = TrainSpec(arch="qwen2.5-0.5b", reduced=True, engine=engine,
                     quantize=quantize, steps=steps, seq=32, batch=2,
                     ckpt_dir=os.path.join(tdir, "ckpt"),
                     telemetry="on", telemetry_dir=tdir, quiet=True)
    result = Trainer.from_spec(spec).fit()
    m = result.metrics
    wm = m.get("watermark", {})
    reg = m.get("registry", {})
    hist = reg.get("train.step_seconds", {})
    return {"engine": engine, "quantize": quantize,
            "steps": len(result.history),
            "final_loss": round(result.final_loss, 6),
            "measured_peak_mb": wm.get("measured_peak_mb", 0.0),
            "predicted_peak_mb": wm.get("predicted_peak_mb", 0.0),
            "ratio": wm.get("ratio", 0.0),
            "source": wm.get("source", ""),
            "mean_step_s": round(hist.get("mean", 0.0), 4),
            "events": m.get("events_by_kind", {})}


def run_sweep(out: str, steps: int = SWEEP_STEPS) -> dict:
    import shutil
    import tempfile

    import jax

    from repro.kernels import ops
    from repro.telemetry import SCHEMA_VERSION

    workdir = tempfile.mkdtemp(prefix="bench_telemetry_")
    rows = []
    try:
        for engine in SWEEP_ENGINES:
            for quantize in SWEEP_QUANTIZE:
                rows.append(sweep_row(engine, quantize, steps, workdir))
                r = rows[-1]
                print(f"  {engine}/{quantize}: measured "
                      f"{r['measured_peak_mb']} MB vs predicted "
                      f"{r['predicted_peak_mb']} MB (ratio {r['ratio']}, "
                      f"source={r['source']})")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    interp = ops.pallas_interpret()
    doc = {
        "benchmark": "telemetry",
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "interpret": interp,
        "note": ("CPU/interpret: memory_stats() unavailable — the "
                 "live_arrays source lower-bounds the true peak (in-jit "
                 "temporaries invisible), so the measured/predicted ratio "
                 "is annotate-only here" if interp else
                 "device allocator stats; ratio is comparable"),
        "setting": {"arch": "qwen2.5-0.5b", "reduced": True, "steps": steps,
                    "seq": 32, "batch": 2,
                    "engines": list(SWEEP_ENGINES),
                    "quantize": list(SWEEP_QUANTIZE)},
        "rows": rows,
    }
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out}")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", default=None, metavar="DIR",
                    help="telemetry run directory (JSONL + trace.json)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check every record; exit 1 on problems")
    ap.add_argument("--expect-kinds", default="",
                    help="comma-separated kinds that must appear (with "
                         "--validate)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the engine×quantize telemetry sweep and "
                         "write BENCH_telemetry.json")
    ap.add_argument("--steps", type=int, default=SWEEP_STEPS,
                    help="steps per sweep fit")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="sweep output path (default: committed baseline)")
    args = ap.parse_args(argv)

    if args.sweep:
        run_sweep(args.out, steps=args.steps)
        return 0
    if not args.run:
        ap.error("pass --run DIR (report/validate) or --sweep")
    records = load_run(args.run)
    if not records:
        print(f"FAIL: no JSONL records under {args.run}")
        return 1
    if args.validate:
        kinds = [k for k in args.expect_kinds.split(",") if k]
        errors = validate(records, kinds)
        for e in errors:
            print(f"FAIL: {e}")
        if errors:
            return 1
        print(f"OK: {len(records)} records valid "
              f"(schema v{ev.SCHEMA_VERSION}"
              + (f"; kinds cover {kinds}" if kinds else "") + ")")
        return 0
    print(json.dumps(summarize(records, args.run), indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
