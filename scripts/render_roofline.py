"""Render EXPERIMENTS.md §Roofline tables from a dry-run JSON dump."""
import json
import sys


def main(path):
    rs = json.load(open(path))
    for mesh in ("16x16", "2x16x16"):
        ok = [r for r in rs if r.get("status") == "ok" and r["mesh"] == mesh]
        if not ok:
            continue
        print(f"\n### Mesh {mesh} ({256 if mesh == '16x16' else 512} chips)\n")
        print("| arch | shape | t_compute | t_memory | t_collective | "
              "dominant | MODEL_FLOPS | useful | roofline frac |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g}s "
                  f"| {r['t_memory_s']:.3g}s | {r['t_collective_s']:.3g}s "
                  f"| {r['dominant']} | {r['model_flops']:.3g} "
                  f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} |")
    skips = [r for r in rs if r.get("status") == "skip"]
    if skips:
        print("\n### Skipped cells\n")
        seen = set()
        for r in skips:
            k = (r["arch"], r["shape"])
            if k in seen:
                continue
            seen.add(k)
            print(f"* {r['arch']} × {r['shape']}: {r['reason']}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results_final.json")
