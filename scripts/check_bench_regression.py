"""CI guard: the sparse-grid flash kernels must stay sparse.

Compares a freshly produced ``BENCH_kernels.json`` against the committed
baseline (``benchmarks/results/BENCH_kernels.json``) on the *deterministic*
sparse-grid columns — live/interior/boundary tile counts, grid fraction and
the effective-FLOPs accounting derived from them. A schedule regression
(> ``TOLERANCE`` more live tiles / higher grid fraction than the baseline,
i.e. the kernels started launching dead tiles again) fails CI.

Wall-clock columns are *not* gated: on non-TPU runners the kernels execute
under the Pallas interpreter (``"interpret": true`` in the JSON), where
timing measures the emulation, not the hardware. Those columns are printed
as annotations only; the committed baseline records which mode produced it.

``--gradquality FRESH.json`` additionally annotates cosine-similarity drift
of a fresh ``benchmarks/gradient_quality.py`` run against the committed
``BENCH_gradient_quality.json`` baseline. Annotation-only, never gated:
per-run cosine is a noisy statistic (SPSA probes), and the CI smoke setting
deliberately differs from the committed full-run setting — the printout
flags both.

``--resilience FRESH.json`` annotates a fresh ``benchmarks/resilience.py``
run (recovery overhead %, steps-to-recover, degradations, loss delta vs the
fault-free twin) against the committed ``BENCH_resilience.json``. Also
annotation-only: wall-clock overhead depends on the host, and the smoke
chaos plan differs from the committed full plan by design. The one hard
check it *does* make: every fault kind the plan injected must have fired.

``--scaling FRESH.json`` gates a fresh ``benchmarks/scaling.py``
device-count curve against the committed ``BENCH_scaling.json``: the
collective-traffic floors (host-independent) are hard checks, the
normalized step-time curve is bounded with generous slack — only an
efficiency *collapse* (sharded program gone super-linear) fails CI.

``--memory FRESH.json`` gates a fresh ``benchmarks/memory_table.py`` run
against the committed ``BENCH_memory.json``. Everything in that table is
pure shape arithmetic (``benchmarks/memsim``) — no wall-clock, no
interpret-mode caveats — so every check is hard: (a) the quantized-stack
residency ratios stay under the format ceilings (int8 ≤ 0.55× bf16, packed
int4/nf4 ≤ 0.30× bf16 — ``MEMORY_CEILINGS``), (b) per-model
``resident_weight_mb`` matches the committed table to ``MEMORY_DRIFT``
(the accounting is deterministic; drift means the memory model changed
without regenerating the baseline), and (c) the serving residency split
covers every swept format.

``--telemetry FRESH.json`` gates a fresh ``scripts/telemetry_report.py
--sweep`` run against the committed ``BENCH_telemetry.json``: the event
schema version, engine × quantize row coverage, and per-row event census
(run/step/watermark kinds present, nonzero measured peak) are hard checks.
The measured-vs-predicted peak ratio itself is annotate-only on CPU, where
``memory_stats()`` is unavailable and the ``live_arrays`` fallback
lower-bounds the true peak.

``--serving FRESH.json`` gates a fresh ``benchmarks/serving.py`` run
against the committed ``BENCH_serving.json``. Hard checks are the
deterministic columns: the grouped-kernel schedule (live-tile count and
grid fraction, with ``TOLERANCE`` slack — launching tiles for idle tenants
again is a regression), grouped-vs-loop numerical agreement, and full
completion of the serving trace (every admitted request finished). The
grouped-vs-loop speedup ratio and tokens/s are wall-clock: annotation-only
under the interpreter, same as the kernels gate.

    PYTHONPATH=src python -m benchmarks.kernels --steps 2 --out /tmp/f.json
    PYTHONPATH=src python scripts/check_bench_regression.py /tmp/f.json
    PYTHONPATH=src python scripts/check_bench_regression.py \\
        --gradquality /tmp/BENCH_gradient_quality_fresh.json
    PYTHONPATH=src python scripts/check_bench_regression.py \\
        --resilience /tmp/BENCH_resilience_fresh.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
            "results" / "BENCH_kernels.json")
GQ_BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
               "results" / "BENCH_gradient_quality.json")
RES_BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
                "results" / "BENCH_resilience.json")
SCALING_BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
                    "results" / "BENCH_scaling.json")
SERVING_BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
                    "results" / "BENCH_serving.json")
MEMORY_BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
                   "results" / "BENCH_memory.json")
TELEMETRY_BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
                      "results" / "BENCH_telemetry.json")

#: --memory ceilings on the quantized-stack residency ratio (vs bf16): the
#: format's ideal compression (0.5x int8, 0.25x packed 4-bit) plus scale-row
#: headroom. A format whose kernels stopped packing blows straight through.
MEMORY_CEILINGS = {"int8": 0.55, "int4": 0.30, "nf4": 0.30}

#: --memory fresh-vs-baseline tolerance: the table is pure shape arithmetic,
#: so any drift beyond float noise means the memory model changed without
#: the committed baseline being regenerated.
MEMORY_DRIFT = 1e-6

#: grouped-vs-loop max abs error ceiling for --serving (float32 comparators
#: computing the same math — anything above this is a kernel bug, not noise)
SERVING_ERR = 1e-4

#: efficiency-collapse bound for --scaling: a fleet's step time normalized
#: by its own 1-device row may exceed the committed normalized curve by at
#: most this factor. Wall-clock on shared CI hosts is noisy, hence the slack
#: — but a sharded program gone quadratic blows through 3x immediately.
SCALING_COLLAPSE = 3.0

#: fractional worsening allowed before failing (a schedule is deterministic,
#: so any change at all is suspicious — 10% leaves room for deliberate
#: block-size retuning that slightly shifts the tile grid)
TOLERANCE = 0.10

#: sparse-grid columns where *larger* is a regression
GATED_UP = ("live_tiles", "boundary_tiles", "grid_fraction")
#: annotation-only wall-clock columns
ANNOTATE = ("sparse_fwdbwd_ms", "dense_fwdbwd_ms", "dense_over_sparse",
            "effective_tflops", "rope_fused_fwd_ms",
            "rope_prerotated_fwd_ms")


def _sg(doc: dict, name: str) -> dict:
    try:
        return doc["per_op"]["attention_sparse_grid"]
    except KeyError:
        raise SystemExit(f"FAIL: {name} has no per_op.attention_sparse_grid "
                         f"section — did benchmarks/kernels.py run?")


def check(fresh_doc: dict, base_doc: dict) -> list[str]:
    fresh, base = _sg(fresh_doc, "fresh"), _sg(base_doc, "baseline")
    errors = []
    if fresh.get("shape") != base.get("shape"):
        print(f"note: bench shape changed {base.get('shape')} -> "
              f"{fresh.get('shape')}; comparing fractions only")
        gated = ("grid_fraction",)
    else:
        gated = GATED_UP
    for col in gated:
        b, f = float(base[col]), float(fresh[col])
        if f > b * (1 + TOLERANCE):
            errors.append(f"{col}: {f:g} vs baseline {b:g} "
                          f"(>{TOLERANCE:.0%} more launched tiles)")
        else:
            print(f"OK: {col} = {f:g} (baseline {b:g})")
    for doc, tag in ((fresh_doc, "fresh"), (base_doc, "baseline")):
        if doc.get("interpret"):
            print(f"note: {tag} run is interpret-mode "
                  f"(backend={doc.get('backend')}) — wall-clock columns "
                  f"measure the Pallas emulation, not TPU perf")
    for col in ANNOTATE:
        if col in fresh:
            extra = f" (baseline {base[col]:.3f})" if col in base else ""
            print(f"   {col}: {fresh[col]:.3f}{extra}")
    return errors


def annotate_gradquality(fresh_doc: dict, base_doc: dict) -> None:
    """Print cosine-similarity drift per ZO engine vs the committed
    gradient-quality baseline. Never fails: per-run cosine is noisy and the
    smoke setting differs from the committed one by design."""
    fs, bs = fresh_doc.get("setting", {}), base_doc.get("setting", {})
    if fs != bs:
        print(f"note: gradquality settings differ (fresh {fs} vs baseline "
              f"{bs}) — drift figures are indicative only")
    fresh_e = fresh_doc.get("engines", {})
    base_e = base_doc.get("engines", {})
    for name in fresh_e:
        f = fresh_e[name].get("cosine_mean")
        b = base_e.get(name, {}).get("cosine_mean")
        if f is None:
            print(f"   gradquality {name}: no cosine_mean in fresh run "
                  f"(partial run / schema mismatch?)")
        elif b is None:
            print(f"   gradquality {name}: cosine {f:+.4f} "
                  f"(no baseline entry — newly registered engine?)")
        else:
            print(f"   gradquality {name}: cosine {f:+.4f} "
                  f"(baseline {b:+.4f}, drift {f - b:+.4f})")
    for name in sorted(set(base_e) - set(fresh_e)):
        print(f"   gradquality {name}: in baseline but missing from fresh "
              f"run — engine unregistered?")


def annotate_resilience(fresh_doc: dict, base_doc: dict) -> list[str]:
    """Print recovery-cost drift vs the committed chaos baseline. Wall-clock
    and loss figures are annotation-only (host- and setting-dependent); the
    only gated condition is that every injected fault kind actually fired —
    a chaos run where a fault silently failed to inject tests nothing."""
    errors = []
    fp = fresh_doc.get("setting", {}).get("plan")
    bp = base_doc.get("setting", {}).get("plan")
    if fp != bp:
        print(f"note: chaos plans differ (fresh {fp!r} vs baseline {bp!r}) "
              f"— recovery figures are indicative only")
    fm = fresh_doc.get("metrics", {})
    bm = base_doc.get("metrics", {})
    for col in ("recovery_overhead_pct", "steps_to_recover",
                "degradation_events", "loss_delta"):
        f, b = fm.get(col), bm.get(col)
        if f is None:
            print(f"   resilience {col}: missing from fresh run")
        else:
            extra = f" (baseline {b})" if b is not None else ""
            print(f"   resilience {col}: {f}{extra}")
    chaos = fresh_doc.get("chaos", {})
    fired = chaos.get("counters", {}).get("injected", {})
    planned = {e.split("@")[0] for e in (fp or "").split(",") if "@" in e}
    missing = sorted(planned - set(fired))
    if missing:
        errors.append(f"resilience: planned fault kind(s) never fired: "
                      f"{missing} (fired: {fired})")
    else:
        print(f"OK: all planned fault kinds fired: {sorted(fired)}")
    if chaos.get("degradations"):
        print(f"   resilience final spec after "
              f"{chaos['degradations']}: {chaos.get('final_spec')} "
              f"(predicted peak {chaos.get('final_predicted_peak_mb')} MB)")
    return errors


def check_scaling(fresh_doc: dict, base_doc: dict) -> list[str]:
    """Gate the device-count scaling curve (``benchmarks/scaling.py``).

    Hard (host-independent) checks:
      * the fresh curve covers every baseline device count;
      * every multi-data-shard program still all-reduces at least the
        analytic gradient-sync floor (its own ``predicted_grad_sync_bytes``)
        — a program that silently lost its gradient sync is wrong, not fast;
      * the single-device program has no collectives.

    Efficiency collapse (the only wall-clock gate, with ``SCALING_COLLAPSE``
    slack): normalized step time (vs the fresh run's own 1-device row) must
    not exceed the committed normalized curve by more than the slack factor.
    """
    errors = []
    fresh = {r["devices"]: r for r in fresh_doc.get("rows", [])}
    base = {r["devices"]: r for r in base_doc.get("rows", [])}
    missing = sorted(set(base) - set(fresh))
    if missing:
        return [f"scaling: fresh curve missing device counts {missing}"]
    for n in sorted(fresh):
        row = fresh[n]
        pred = row.get("predicted_grad_sync_bytes", 0)
        ar = row.get("collective_bytes", {}).get("all-reduce", 0)
        total = row.get("collective_bytes_total", 0)
        dp = n // max(row.get("model_parallel", 1), 1)
        if n == 1 and total != 0:
            errors.append(f"scaling: 1-device program emits collectives "
                          f"({total} bytes)")
        if dp > 1 and ar < pred:
            errors.append(f"scaling: {n}-device all-reduce {ar}B below the "
                          f"gradient-sync floor {pred}B — lost collectives?")
    f1 = fresh.get(1, {}).get("step_time_s")
    b1 = base.get(1, {}).get("step_time_s")
    for n in sorted(fresh):
        if n == 1 or f1 is None or b1 is None or n not in base:
            continue
        f_ratio = fresh[n]["step_time_s"] / f1
        b_ratio = base[n]["step_time_s"] / b1
        if f_ratio > b_ratio * SCALING_COLLAPSE:
            errors.append(
                f"scaling: {n}-device step time {f_ratio:.2f}x of 1-device "
                f"(baseline {b_ratio:.2f}x; allowed {SCALING_COLLAPSE}x "
                f"slack) — efficiency collapse")
        else:
            print(f"OK: scaling {n}dev normalized step {f_ratio:.2f}x "
                  f"(baseline {b_ratio:.2f}x)")
        print(f"   scaling {n}dev: step {fresh[n]['step_time_s'] * 1e3:.1f}ms"
              f" coll_total {fresh[n].get('collective_bytes_total', 0)}B "
              f"(baseline {base[n].get('collective_bytes_total', 0)}B)")
    return errors


def check_serving(fresh_doc: dict, base_doc: dict) -> list[str]:
    """Gate the multi-tenant serving benchmark (``benchmarks/serving.py``).

    Hard (host-independent) checks:
      * grouped-kernel schedule: live tiles / grid fraction within
        ``TOLERANCE`` of the committed baseline (idle tenants must keep
        being skipped);
      * grouped kernel ≡ per-adapter loop within ``SERVING_ERR``;
      * the continuous trace completed every admitted request, and the
        multi-tenant trace actually exercised multi-tenancy (>1 adapter).

    Tokens/s and the loop-over-grouped ratio are wall-clock: annotated,
    with the interpret-mode caveat printed when either run used it.
    """
    errors = []
    fgk = fresh_doc.get("grouped_kernel", {})
    bgk = base_doc.get("grouped_kernel", {})
    fs, bs = fgk.get("schedule", {}), bgk.get("schedule", {})
    if not fs or not bs:
        return ["serving: missing grouped_kernel.schedule section "
                "(did benchmarks/serving.py run?)"]
    if fgk.get("shape") != bgk.get("shape"):
        print(f"note: serving kernel shape changed {bgk.get('shape')} -> "
              f"{fgk.get('shape')}; comparing grid fraction only")
        gated = ("grid_fraction",)
    else:
        gated = ("live_tiles", "grid_fraction")
    for col in gated:
        b, f = float(bs[col]), float(fs[col])
        if f > b * (1 + TOLERANCE):
            errors.append(f"serving {col}: {f:g} vs baseline {b:g} "
                          f"(>{TOLERANCE:.0%} more launched tiles — idle "
                          f"tenants no longer skipped?)")
        else:
            print(f"OK: serving {col} = {f:g} (baseline {b:g})")
    err = float(fgk.get("max_abs_err", float("inf")))
    if err > SERVING_ERR:
        errors.append(f"serving grouped-vs-loop max |err| {err:g} exceeds "
                      f"{SERVING_ERR:g} — grouped kernel diverged from the "
                      f"per-adapter reference")
    else:
        print(f"OK: serving grouped-vs-loop max |err| {err:g}")
    for key in ("multi", "single"):
        c = fresh_doc.get("continuous", {}).get(key, {})
        admitted = c.get("counters", {}).get("admitted")
        completed = c.get("completed")
        if admitted is None or completed != admitted:
            errors.append(f"serving {key}: completed {completed} of "
                          f"{admitted} admitted requests — trace stalled")
        else:
            print(f"OK: serving {key} completed {completed}/{admitted} "
                  f"requests")
    multi = fresh_doc.get("continuous", {}).get("multi", {})
    if multi.get("adapters", 0) < 2:
        errors.append(f"serving: multi trace served "
                      f"{multi.get('adapters')} adapter(s) — not a "
                      f"multi-tenant run")
    for doc, tag in ((fresh_doc, "fresh"), (base_doc, "baseline")):
        if doc.get("interpret"):
            print(f"note: {tag} serving run is interpret-mode "
                  f"(backend={doc.get('backend')}) — tokens/s and the "
                  f"loop-over-grouped ratio measure the Pallas emulation, "
                  f"not TPU perf")
    for key in ("multi", "single"):
        fc = fresh_doc.get("continuous", {}).get(key, {})
        bc = base_doc.get("continuous", {}).get(key, {})
        if "tokens_per_s" in fc:
            extra = (f" (baseline {bc['tokens_per_s']:.1f})"
                     if "tokens_per_s" in bc else "")
            print(f"   serving {key} tokens/s: "
                  f"{fc['tokens_per_s']:.1f}{extra}")
    if "loop_over_grouped" in fgk:
        extra = (f" (baseline {bgk['loop_over_grouped']:.3f})"
                 if "loop_over_grouped" in bgk else "")
        print(f"   serving loop_over_grouped: "
              f"{fgk['loop_over_grouped']:.3f}{extra}")
    return errors


def check_memory(fresh_doc: dict, base_doc: dict) -> list[str]:
    """Gate the analytic HBM-residency table (``benchmarks/memory_table.py``).

    All checks are hard — the table contains no measured quantity:
      * per model and quantized format, ``quantized_ratio_vs_bf16`` must
        stay under the ``MEMORY_CEILINGS`` ceiling (the format's promised
        compression on the bytes it controls);
      * per model and format, ``resident_weight_mb`` must match the
        committed baseline to ``MEMORY_DRIFT`` relative — drift means the
        memory model changed without regenerating the baseline;
      * the serving residency section must carry a split (with weights_mb)
        for every swept format.
    """
    errors = []
    fresh_models = fresh_doc.get("models", {})
    base_models = base_doc.get("models", {})
    if not fresh_models:
        return ["memory: fresh table has no models section — did "
                "benchmarks/memory_table.py run?"]
    for arch, row in sorted(fresh_models.items()):
        for fmt, ceil in sorted(MEMORY_CEILINGS.items()):
            r = row.get("quantized_ratio_vs_bf16", {}).get(fmt)
            if r is None:
                errors.append(f"memory {arch}: no quantized ratio for "
                              f"{fmt} — format dropped from the sweep?")
            elif r > ceil:
                errors.append(f"memory {arch}: {fmt} quantized-stack ratio "
                              f"{r:.4f} exceeds the {ceil:.2f}x ceiling — "
                              f"packing regressed")
            else:
                print(f"OK: memory {arch} {fmt} ratio {r:.4f} "
                      f"(ceiling {ceil:.2f})")
        base_w = base_models.get(arch, {}).get("resident_weight_mb", {})
        for fmt, mb in sorted(row.get("resident_weight_mb", {}).items()):
            b = base_w.get(fmt)
            if b is None:
                print(f"   memory {arch} {fmt}: {mb:.1f} MB "
                      f"(no baseline entry — new format/model)")
            elif abs(mb - b) > MEMORY_DRIFT * max(abs(b), 1.0):
                errors.append(f"memory {arch} {fmt}: resident "
                              f"{mb:.4f} MB vs committed {b:.4f} MB — "
                              f"model changed, regenerate the baseline")
    fmts = fresh_doc.get("formats", [])
    resid = fresh_doc.get("serving", {}).get("residency", {})
    missing = [f for f in fmts
               if "weights_mb" not in resid.get(f, {})]
    if missing:
        errors.append(f"memory: serving residency split missing for "
                      f"format(s) {missing}")
    elif fmts:
        parts = ", ".join(f"{f}={resid[f]['weights_mb']:.0f}" for f in fmts)
        print(f"OK: serving residency split covers all formats "
              f"(weights MB: {parts})")
    return errors


def check_telemetry(fresh_doc: dict, base_doc: dict) -> list[str]:
    """Gate a fresh ``scripts/telemetry_report.py --sweep`` run
    (``BENCH_telemetry.json``) against the committed baseline.

    Hard (host-independent) checks:
      * the event schema version matches the committed baseline — a bumped
        ``repro.telemetry.events.SCHEMA_VERSION`` must regenerate it;
      * the fresh sweep covers every baseline engine × quantize row;
      * every row carries the required fields, a nonzero measured peak, and
        a per-row event census that includes run + step + watermark kinds.

    The measured/predicted ratio is annotate-only on CPU/interpret hosts:
    ``memory_stats()`` is unavailable there, and the ``live_arrays``
    fallback lower-bounds the true peak (in-jit temporaries are invisible).
    On a device-stats backend the same column becomes a meaningful
    cross-check of the paper's peak-memory claim.
    """
    errors = []
    fv = fresh_doc.get("schema_version")
    bv = base_doc.get("schema_version")
    if fv != bv:
        errors.append(f"telemetry: schema_version {fv!r} != committed "
                      f"{bv!r} — regenerate the baseline after a schema "
                      f"bump")
    else:
        print(f"OK: telemetry schema v{fv}")
    key = lambda r: (r.get("engine"), r.get("quantize"))  # noqa: E731
    fresh = {key(r): r for r in fresh_doc.get("rows", [])}
    base = {key(r): r for r in base_doc.get("rows", [])}
    missing = sorted(set(base) - set(fresh))
    if missing:
        errors.append(f"telemetry: fresh sweep missing rows {missing}")
    required = ("measured_peak_mb", "predicted_peak_mb", "ratio", "source",
                "steps", "events")
    for k in sorted(fresh):
        row = fresh[k]
        absent = [f for f in required if f not in row]
        if absent:
            errors.append(f"telemetry {k}: missing fields {absent}")
            continue
        if not row["measured_peak_mb"] > 0:
            errors.append(f"telemetry {k}: measured peak "
                          f"{row['measured_peak_mb']} MB — watermark never "
                          f"sampled?")
        kinds = set(row["events"])
        need = {"run", "step", "watermark"}
        if not need <= kinds:
            errors.append(f"telemetry {k}: event census missing "
                          f"{sorted(need - kinds)} (got {sorted(kinds)})")
    if not errors:
        for k in sorted(fresh):
            row, brow = fresh[k], base.get(k, {})
            extra = (f" (baseline {brow['ratio']})" if "ratio" in brow
                     else "")
            print(f"   telemetry {k[0]}/{k[1]}: measured "
                  f"{row['measured_peak_mb']} MB vs predicted "
                  f"{row['predicted_peak_mb']} MB, ratio "
                  f"{row['ratio']}{extra} [source={row['source']}]")
    if fresh_doc.get("interpret"):
        print("note: fresh telemetry sweep is CPU/interpret — "
              "measured/predicted ratio is annotate-only (live_arrays "
              "lower-bounds the true peak)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", nargs="?", default=None,
                    help="freshly written BENCH_kernels.json")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--gradquality", default=None, metavar="FRESH_JSON",
                    help="annotate a fresh BENCH_gradient_quality.json "
                         "against the committed baseline (never gated)")
    ap.add_argument("--gq-baseline", default=str(GQ_BASELINE))
    ap.add_argument("--resilience", default=None, metavar="FRESH_JSON",
                    help="annotate a fresh BENCH_resilience.json against "
                         "the committed baseline (gated only on every "
                         "planned fault kind having fired)")
    ap.add_argument("--res-baseline", default=str(RES_BASELINE))
    ap.add_argument("--scaling", default=None, metavar="FRESH_JSON",
                    help="gate a fresh BENCH_scaling.json against the "
                         "committed device-count curve (collective floors "
                         "hard; step-time collapse with slack)")
    ap.add_argument("--scaling-baseline", default=str(SCALING_BASELINE))
    ap.add_argument("--serving", default=None, metavar="FRESH_JSON",
                    help="gate a fresh BENCH_serving.json against the "
                         "committed baseline (schedule + equivalence + "
                         "completion hard; tokens/s annotate-only)")
    ap.add_argument("--serving-baseline", default=str(SERVING_BASELINE))
    ap.add_argument("--memory", default=None, metavar="FRESH_JSON",
                    help="gate a fresh BENCH_memory.json against the "
                         "committed baseline (all hard: format residency "
                         "ceilings + drift + serving split coverage)")
    ap.add_argument("--memory-baseline", default=str(MEMORY_BASELINE))
    ap.add_argument("--telemetry", default=None, metavar="FRESH_JSON",
                    help="gate a fresh BENCH_telemetry.json against the "
                         "committed baseline (schema version + row "
                         "coverage + event census hard; measured/predicted "
                         "ratio annotate-only on CPU)")
    ap.add_argument("--telemetry-baseline", default=str(TELEMETRY_BASELINE))
    args = ap.parse_args(argv)
    if args.fresh is None and args.gradquality is None \
            and args.resilience is None and args.scaling is None \
            and args.serving is None and args.memory is None \
            and args.telemetry is None:
        ap.error("nothing to do: pass a fresh BENCH_kernels.json, "
                 "--gradquality, --resilience, --scaling, --serving, "
                 "--memory, and/or --telemetry")

    errors = []
    if args.fresh is not None:
        with open(args.fresh) as f:
            fresh = json.load(f)
        with open(args.baseline) as f:
            base = json.load(f)
        errors = check(fresh, base)
        for e in errors:
            print(f"FAIL: {e}")
        if not errors:
            print("OK: sparse-grid columns within tolerance of the baseline")

    if args.gradquality is not None:
        with open(args.gradquality) as f:
            gq_fresh = json.load(f)
        with open(args.gq_baseline) as f:
            gq_base = json.load(f)
        annotate_gradquality(gq_fresh, gq_base)

    if args.resilience is not None:
        with open(args.resilience) as f:
            res_fresh = json.load(f)
        with open(args.res_baseline) as f:
            res_base = json.load(f)
        res_errors = annotate_resilience(res_fresh, res_base)
        for e in res_errors:
            print(f"FAIL: {e}")
        errors += res_errors

    if args.scaling is not None:
        with open(args.scaling) as f:
            sc_fresh = json.load(f)
        with open(args.scaling_baseline) as f:
            sc_base = json.load(f)
        sc_errors = check_scaling(sc_fresh, sc_base)
        for e in sc_errors:
            print(f"FAIL: {e}")
        if not sc_errors:
            print("OK: scaling curve within tolerance of the baseline")
        errors += sc_errors

    if args.serving is not None:
        with open(args.serving) as f:
            sv_fresh = json.load(f)
        with open(args.serving_baseline) as f:
            sv_base = json.load(f)
        sv_errors = check_serving(sv_fresh, sv_base)
        for e in sv_errors:
            print(f"FAIL: {e}")
        if not sv_errors:
            print("OK: serving schedule/equivalence/completion within "
                  "tolerance of the baseline")
        errors += sv_errors

    if args.memory is not None:
        with open(args.memory) as f:
            mem_fresh = json.load(f)
        with open(args.memory_baseline) as f:
            mem_base = json.load(f)
        mem_errors = check_memory(mem_fresh, mem_base)
        for e in mem_errors:
            print(f"FAIL: {e}")
        if not mem_errors:
            print("OK: memory table within the format ceilings and "
                  "matching the committed baseline")
        errors += mem_errors

    if args.telemetry is not None:
        with open(args.telemetry) as f:
            tel_fresh = json.load(f)
        with open(args.telemetry_baseline) as f:
            tel_base = json.load(f)
        tel_errors = check_telemetry(tel_fresh, tel_base)
        for e in tel_errors:
            print(f"FAIL: {e}")
        if not tel_errors:
            print("OK: telemetry sweep schema/coverage matches the "
                  "committed baseline")
        errors += tel_errors

    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
