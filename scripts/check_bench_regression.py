"""CI guard: the sparse-grid flash kernels must stay sparse.

Compares a freshly produced ``BENCH_kernels.json`` against the committed
baseline (``benchmarks/results/BENCH_kernels.json``) on the *deterministic*
sparse-grid columns — live/interior/boundary tile counts, grid fraction and
the effective-FLOPs accounting derived from them. A schedule regression
(> ``TOLERANCE`` more live tiles / higher grid fraction than the baseline,
i.e. the kernels started launching dead tiles again) fails CI.

Wall-clock columns are *not* gated: on non-TPU runners the kernels execute
under the Pallas interpreter (``"interpret": true`` in the JSON), where
timing measures the emulation, not the hardware. Those columns are printed
as annotations only; the committed baseline records which mode produced it.

    PYTHONPATH=src python -m benchmarks.kernels --steps 2 --out /tmp/f.json
    PYTHONPATH=src python scripts/check_bench_regression.py /tmp/f.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = (Path(__file__).resolve().parent.parent / "benchmarks" /
            "results" / "BENCH_kernels.json")

#: fractional worsening allowed before failing (a schedule is deterministic,
#: so any change at all is suspicious — 10% leaves room for deliberate
#: block-size retuning that slightly shifts the tile grid)
TOLERANCE = 0.10

#: sparse-grid columns where *larger* is a regression
GATED_UP = ("live_tiles", "boundary_tiles", "grid_fraction")
#: annotation-only wall-clock columns
ANNOTATE = ("sparse_fwdbwd_ms", "dense_fwdbwd_ms", "dense_over_sparse",
            "effective_tflops", "rope_fused_fwd_ms",
            "rope_prerotated_fwd_ms")


def _sg(doc: dict, name: str) -> dict:
    try:
        return doc["per_op"]["attention_sparse_grid"]
    except KeyError:
        raise SystemExit(f"FAIL: {name} has no per_op.attention_sparse_grid "
                         f"section — did benchmarks/kernels.py run?")


def check(fresh_doc: dict, base_doc: dict) -> list[str]:
    fresh, base = _sg(fresh_doc, "fresh"), _sg(base_doc, "baseline")
    errors = []
    if fresh.get("shape") != base.get("shape"):
        print(f"note: bench shape changed {base.get('shape')} -> "
              f"{fresh.get('shape')}; comparing fractions only")
        gated = ("grid_fraction",)
    else:
        gated = GATED_UP
    for col in gated:
        b, f = float(base[col]), float(fresh[col])
        if f > b * (1 + TOLERANCE):
            errors.append(f"{col}: {f:g} vs baseline {b:g} "
                          f"(>{TOLERANCE:.0%} more launched tiles)")
        else:
            print(f"OK: {col} = {f:g} (baseline {b:g})")
    for doc, tag in ((fresh_doc, "fresh"), (base_doc, "baseline")):
        if doc.get("interpret"):
            print(f"note: {tag} run is interpret-mode "
                  f"(backend={doc.get('backend')}) — wall-clock columns "
                  f"measure the Pallas emulation, not TPU perf")
    for col in ANNOTATE:
        if col in fresh:
            extra = f" (baseline {base[col]:.3f})" if col in base else ""
            print(f"   {col}: {fresh[col]:.3f}{extra}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly written BENCH_kernels.json")
    ap.add_argument("--baseline", default=str(BASELINE))
    args = ap.parse_args(argv)
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    errors = check(fresh, base)
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print("OK: sparse-grid columns within tolerance of the baseline")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
