"""Structured-vs-pallas kernel benchmark — tracks the kernel path's perf
trajectory from the PR that wired it in.

    PYTHONPATH=src python -m benchmarks.kernels [--steps 5] [--out PATH]

Times one full MeSP train step per mode (``structured`` jnp custom_vjp rules
vs ``pallas`` fused kernels) plus per-op microbenchmarks, and writes
``benchmarks/results/BENCH_kernels.json``. On non-TPU backends the kernels
run under the Pallas interpreter — those numbers track *correctness cost*
only and are flagged ``interpret: true`` in the JSON; real speedups are a
TPU measurement. With ``REPRO_AUTOTUNE=1`` the per-op section sweeps the
autotuner's candidate block sizes, records the measured winners and
persists them to the checked-in per-backend cache
(``kernels/autotune_cache/<backend>.json``).

The ``attention_sparse_grid`` section carries the *measured* sparse-grid
accounting: live/interior/boundary tile counts and grid fraction straight
from the trace-time schedule (deterministic — what
``scripts/check_bench_regression.py`` guards), plus sparse-vs-dense-grid
wall clock and the effective FLOP throughput over live tiles.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DEFAULT_OUT = os.path.join(RESULTS_DIR, "BENCH_kernels.json")


def _time(fn, *args, repeats=3):
    fn(*args)  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_train_step(steps: int):
    """Per-step wall time of mesp.train_step for each mode, with and
    without int8-quantized base weights (``*_int8`` entries)."""
    from repro.api import ExecutionPolicy
    from repro.configs.base import ArchConfig
    from repro.core import mesp
    from repro.models import model as M

    cfg = ArchConfig(name="bench-dense", family="dense", n_layers=2,
                     d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                     vocab=512, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    params_q = M.init_params(jax.random.PRNGKey(0), cfg, quantize="int8")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    out = {}
    for name, backend, p0 in (("structured", "structured", params),
                              ("pallas", "pallas", params),
                              ("structured_int8", "structured", params_q),
                              ("pallas_int8", "pallas", params_q)):
        policy = ExecutionPolicy(backend=backend)
        step = jax.jit(lambda p, b, pol=policy: mesp.train_step(
            p, cfg, b, 1e-3, policy=pol))
        p, _ = step(p0, batch)                  # compile
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for _ in range(steps):
            p, loss = step(p, batch)
        jax.block_until_ready(loss)
        out[name] = {"step_ms": (time.perf_counter() - t0) / steps * 1e3,
                     "final_loss": float(loss)}
    out["pallas_over_structured"] = (out["pallas"]["step_ms"] /
                                     out["structured"]["step_ms"])
    out["int8_over_bf16_pallas"] = (out["pallas_int8"]["step_ms"] /
                                    out["pallas"]["step_ms"])
    return out, {"arch": cfg.name, "d_model": cfg.d_model,
                 "n_layers": cfg.n_layers, "seq": 128, "batch": 1}


def bench_ops():
    """Per-op micro timings: kernel vs the jnp path it replaces."""
    from repro.core import structured
    from repro.kernels import autotune, ops

    interp = ops.pallas_interpret()
    key = jax.random.PRNGKey(0)
    M_, K, N, r = 512, 896, 896, 8
    x = jax.random.normal(key, (M_, K)) * 0.3
    w0 = jax.random.normal(key, (K, N)) * 0.05
    a = jax.random.normal(key, (K, r)) * 0.3
    b = jax.random.normal(key, (r, N)) * 0.3
    g = jax.random.normal(key, (M_, N)) * 0.3
    w = jax.random.normal(key, (K,))

    out = {}
    # LoRA linear fwd
    f_pl = jax.jit(lambda x: ops.lora_linear(x, w0, a, b, None, 2.0))
    f_jnp = jax.jit(lambda x: structured.lora_linear(x, w0, a, b, None, 2.0))
    out["lora_fwd"] = {"pallas_ms": _time(f_pl, x) * 1e3,
                       "structured_ms": _time(f_jnp, x) * 1e3}
    # fused dA/dB vs three jnp matmuls
    from repro.kernels.lora_fused import lora_dab
    d_pl = jax.jit(lambda x, g: lora_dab(x, g, a, b, 2.0, interpret=interp))
    d_jnp = jax.jit(lambda x, g: ((x).T @ ((2.0 * g) @ b.T),
                                  (x @ a).T @ (2.0 * g)))
    out["lora_dab"] = {"pallas_ms": _time(d_pl, x, g) * 1e3,
                       "structured_ms": _time(d_jnp, x, g) * 1e3}
    # quantized-W0 LoRA: dequant-in-VMEM kernel vs structured on a dequant
    # quantized W0 passed as jit args (not closure constants) so the jnp
    # dequant isn't constant-folded out of the timing
    from repro.core import quant
    qw, sw = quant.quantize_int8(w0)
    fq_pl = jax.jit(lambda x, qw, sw: ops.lora_linear(
        x, {"q": qw, "scale": sw}, a, b, None, 2.0))
    fq_jnp = jax.jit(lambda x, qw, sw: structured.lora_linear(
        x, quant.dequantize_int8(qw, sw, x.dtype), a, b, None, 2.0))
    out["lora_fwd_int8"] = {"pallas_ms": _time(fq_pl, x, qw, sw) * 1e3,
                            "structured_ms": _time(fq_jnp, x, qw, sw) * 1e3}
    gq_pl = jax.jit(jax.grad(lambda x, qw, sw: jnp.sum(ops.lora_linear(
        x, {"q": qw, "scale": sw}, a, b, None, 2.0))))
    gq_jnp = jax.jit(jax.grad(lambda x, qw, sw: jnp.sum(structured.lora_linear(
        x, quant.dequantize_int8(qw, sw, x.dtype), a, b, None, 2.0))))
    out["lora_dx_int8"] = {"pallas_ms": _time(gq_pl, x, qw, sw) * 1e3,
                           "structured_ms": _time(gq_jnp, x, qw, sw) * 1e3}
    # rmsnorm fwd
    n_pl = jax.jit(lambda x: ops.rmsnorm(x, w))
    n_jnp = jax.jit(lambda x: structured.rmsnorm(x, w))
    out["rmsnorm_fwd"] = {"pallas_ms": _time(n_pl, x) * 1e3,
                          "structured_ms": _time(n_jnp, x) * 1e3}
    # flash attention fwd+bwd (sparse grid, through the dispatch custom_vjp)
    B, H, Hkv, Nq, D = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (B, H, Nq, D)) * 0.3
    kk = jax.random.normal(key, (B, Hkv, Nq, D)) * 0.3
    vv = jax.random.normal(key, (B, Hkv, Nq, D)) * 0.3
    a_pl = jax.jit(jax.grad(lambda q: jnp.sum(
        ops.flash_attention(q, kk, vv, True, 0, interp))))
    a_jnp = jax.jit(jax.grad(lambda q: jnp.sum(
        structured.sdpa(q, kk, vv, 0, True))))
    out["attention_grad"] = {"pallas_ms": _time(a_pl, q) * 1e3,
                             "structured_ms": _time(a_jnp, q) * 1e3}
    out["attention_sparse_grid"] = bench_sparse_grid(interp)

    if os.environ.get("REPRO_AUTOTUNE") == "1":
        from repro.kernels import flash_attention as fa
        from repro.kernels.lora_fused import lora_fused
        cands = [{"bm": bm, "bn": bn, "bk": bk}
                 for bm in (128, 256) for bn in (128, 256)
                 for bk in (128, 256)]
        best = autotune.autotune(
            "lora_fused",
            lambda blk: lora_fused(x, w0, a, b, 2.0, interpret=interp, **blk),
            candidates=cands, M=M_, K=K, N=N)
        out["autotuned_lora_fused_blocks"] = best
        qf = q.reshape(B * H, Nq, D)
        kf, vf = kk.reshape(B * Hkv, Nq, D), vv.reshape(B * Hkv, Nq, D)
        best_flash = autotune.autotune(
            "flash",
            lambda blk: fa.flash_attention_fwd(
                qf, kf, vf, causal=True, window=0, q_per_kv=H // Hkv,
                interpret=interp, **blk),
            candidates=[{"bq": a_, "bk": b_} for a_ in (128, 256)
                        for b_ in (128, 256)],
            Nq=Nq, Nk=Nq, D=D, causal=1, window=0)
        out["autotuned_flash_blocks"] = best_flash
        out["autotune_cache_written"] = autotune.save_cache()
    return out


def bench_sparse_grid(interp: bool, Nq: int = 1024, window: int = 0):
    """Sparse vs dense tile grid on a long causal sequence: schedule
    accounting (deterministic) + measured fwd+bwd wall clock + effective
    FLOP throughput over the live tiles. Also measures fused-RoPE vs
    pre-rotated q/k through the same kernel."""
    from repro.kernels import flash_attention as fa
    from repro.kernels.rope import apply_rope_tables, rope_tables
    from repro.kernels.tiling import flash_schedule_stats

    B, H, Hkv, D = 1, 4, 2, 64
    bq = bk = 128
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (B * H, Nq, D)) * 0.3
    k = jax.random.normal(key, (B * Hkv, Nq, D)) * 0.3
    v = jax.random.normal(key, (B * Hkv, Nq, D)) * 0.3
    g = jax.random.normal(key, (B * H, Nq, D)) * 0.3
    kw = dict(causal=True, window=window, bq=bq, bk=bk, q_per_kv=H // Hkv,
              interpret=interp)

    def fwdbwd(sparse):
        def run(q, k, v, g):
            out, lse = fa.flash_attention_fwd(q, k, v, return_lse=True,
                                              sparse=sparse, **kw)
            dq, dk, dv = fa.flash_attention_bwd(q, k, v, out, lse, g,
                                                sparse=sparse, **kw)
            return out, dq, dk, dv
        return jax.jit(run)

    sparse_ms = _time(fwdbwd(True), q, k, v, g) * 1e3
    dense_ms = _time(fwdbwd(False), q, k, v, g) * 1e3

    st = flash_schedule_stats(Nq, Nq, bq, bk, True, window)
    # per live tile and head: fwd s/pv (2 matmuls), bwd-dq s/dp/dq (3),
    # bwd-dkv s/dp/dv/dk (4) — 9 [bq,bk]×[bk,D]-class matmuls at 2·bq·bk·D
    eff_flops = B * H * st["live_tiles"] * 18 * st["bq"] * st["bk"] * D
    dense_flops = B * H * st["dense_tiles"] * 18 * st["bq"] * st["bk"] * D

    cos, sin = rope_tables(jnp.arange(Nq), 10000.0, D)
    f_fused = jax.jit(lambda q, k, v: fa.flash_attention_fwd(
        q, k, v, (cos, sin), **kw))
    f_prerot = jax.jit(lambda q, k, v: fa.flash_attention_fwd(
        apply_rope_tables(q, cos, sin), apply_rope_tables(k, cos, sin),
        v, **kw))
    fused_ms = _time(f_fused, q, k, v) * 1e3
    prerot_ms = _time(f_prerot, q, k, v) * 1e3

    return {
        "shape": {"B": B, "H": H, "Hkv": Hkv, "Nq": Nq, "D": D,
                  "causal": True, "window": window},
        **st,
        "sparse_fwdbwd_ms": sparse_ms,
        "dense_fwdbwd_ms": dense_ms,
        "dense_over_sparse": dense_ms / sparse_ms,
        "effective_tflops": eff_flops / (sparse_ms * 1e-3) / 1e12,
        "dense_grid_tflops": dense_flops / (dense_ms * 1e-3) / 1e12,
        "rope_fused_fwd_ms": fused_ms,
        "rope_prerotated_fwd_ms": prerot_ms,
    }


def run_and_write(steps: int = 5, out: str = DEFAULT_OUT) -> dict:
    """Run both sections, write the JSON artifact, return the result dict.
    Single assembly point — benchmarks/run.py's ``kernels`` table calls this
    too, so the checked-in artifact has one schema."""
    from repro.kernels import ops
    interp = ops.pallas_interpret()
    step, shape = bench_train_step(steps)
    per_op = bench_ops()
    result = {
        "backend": jax.default_backend(),
        "interpret": interp,
        "note": ("interpret mode: pallas numbers measure the emulation, "
                 "not TPU perf") if interp else "compiled TPU kernels",
        "shape": shape,
        "train_step": step,
        "per_op": per_op,
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    result = run_and_write(args.steps, args.out)
    print(json.dumps(result, indent=1, sort_keys=True))
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
