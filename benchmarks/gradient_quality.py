"""Gradient-quality benchmark: every ZO engine vs the exact MeSP gradient.

Reproduces the paper's §5.6 diagnostic (single-probe MeZO cosine ≈ 0.001 —
essentially uncorrelated with the true gradient) and quantifies how much
each structured ZO variant closes the gap, over a real training trajectory
(``repro.zo.gradquality.probe_over_steps``). The engine sweep is generated
from the registry (``backend=None`` + a ``value_and_grad`` hook), so a
newly registered ZO engine joins with zero edits here.

    PYTHONPATH=src python -m benchmarks.gradient_quality            # full
    PYTHONPATH=src python -m benchmarks.gradient_quality --smoke    # CI

Writes ``BENCH_gradient_quality.json`` (committed baseline under
``benchmarks/results/``; ``scripts/check_bench_regression.py --gradquality``
annotates drift against it) and, for full runs, a ``gradquality`` section in
``benchmarks/results/paper_tables.md``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BASELINE = os.path.join(RESULTS_DIR, "BENCH_gradient_quality.json")

#: full-run measurement setting (the committed baseline): 12 steps × 4
#: probes = 48 scored estimates per engine (sem ≈ 0.0004 — enough to
#: separate the structured variants from vanilla mezo's ≈0.005)
FULL = dict(n_layers=6, seq=48, batch=2, steps=12, warmup=10, probes=4)
#: CI smoke setting — same machinery, minutes not tens of minutes
SMOKE = dict(n_layers=3, seq=32, batch=2, steps=3, warmup=6, probes=2)


def run(smoke: bool = False, arch: str = "qwen2.5-0.5b",
        seed: int = 0) -> dict:
    import jax

    from repro.configs import get_config
    from repro.zo import gradquality

    setting = SMOKE if smoke else FULL
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              n_layers=setting["n_layers"])
    engines = gradquality.zo_engine_names()
    t0 = time.monotonic()
    results = gradquality.probe_over_steps(
        engines, cfg, steps=setting["steps"], warmup=setting["warmup"],
        seq=setting["seq"], batch=setting["batch"],
        probes=setting["probes"], seed=seed)
    return {
        "benchmark": "gradient_quality",
        "arch": arch, "reduced": True, "seed": seed,
        "reference": "mesp",
        "setting": dict(setting, smoke=smoke),
        "backend": jax.default_backend(),
        "machine": platform.machine(),
        "elapsed_s": round(time.monotonic() - t0, 1),
        "engines": results,
    }


def render_markdown(doc: dict) -> str:
    s = doc["setting"]
    lines = [
        "## Gradient quality — ZO engines vs exact MeSP gradient "
        "(paper §5.6 / Table 3)",
        f"Reduced {doc['arch']} family, {s['n_layers']} layers, "
        f"seq {s['seq']}, batch {s['batch']}; mean over {s['steps']} "
        f"training steps × {s['probes']} probes after {s['warmup']} "
        "exact-gradient warmup steps. "
        "Single-probe SPSA cosine is near zero for vanilla `mezo` (the "
        "paper's ≈0.001 finding — why MeZO converges slowly); the "
        "structured samplers close part of the gap.",
        "",
        "| engine | mean cosine | ×`mezo` | sign agree | rel. error |",
        "|---|---|---|---|---|",
    ]
    base = doc["engines"].get("mezo", {}).get("cosine_mean")
    for name, r in doc["engines"].items():
        # the ratio column only makes sense against a positive mezo mean
        # (near-zero/negative baselines happen — SPSA cosine is noisy)
        ratio = (f"{r['cosine_mean'] / base:.2f}×"
                 if base is not None and base > 0 else "—")
        lines.append(f"| `{name}` | {r['cosine_mean']:+.4f} | "
                     f"{ratio} | {r['sign_agree_mean']:.1%} | "
                     f"{r['rel_error_mean']:.1f} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer layers/steps), no report merge")
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {BASELINE})")
    args = ap.parse_args(argv)

    doc = run(smoke=args.smoke, arch=args.arch, seed=args.seed)
    out = args.out or BASELINE
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")

    for name, r in doc["engines"].items():
        print(f"gradquality/{name}/cosine_mean,{r['cosine_mean']:.4f},"
              f"sign={r['sign_agree_mean']:.3f} rel={r['rel_error_mean']:.1f}")
    print(f"# wrote {out} ({doc['elapsed_s']}s)")

    if not args.smoke:
        from benchmarks.run import _merge_report
        _merge_report(os.path.join(RESULTS_DIR, "paper_tables.md"),
                      {"gradquality": render_markdown(doc)})
        print(f"# report: {os.path.join(RESULTS_DIR, 'paper_tables.md')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
