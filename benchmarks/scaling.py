"""Scaling-curve benchmark: step time & collective traffic vs device count.

Runs the *same* reduced training program through ``launch/fleet.py`` on
emulated fleets of 1/2/4/8 CPU devices — one subprocess per count, because
``--xla_force_host_platform_device_count`` binds when the XLA backend
initializes — and records, per count:

* median steady-state step time (compile/warm-up step discarded);
* the compiled program's collective payload bytes by kind
  (``roofline.analysis.collective_bytes`` over the sharded step's HLO);
* the analytic gradient-sync floor (``predicted_grad_sync_bytes``).

Emulated devices share one physical CPU, so wall-clock *speedup* is not the
point; the committed curve (``benchmarks/results/BENCH_scaling.json``)
pins the shape of the overhead instead, and
``scripts/check_bench_regression.py --scaling`` gates on efficiency
collapse — a fleet whose normalized step time blows up vs the baseline
curve, or whose programs lost their predicted collectives, fails CI.

    PYTHONPATH=src python -m benchmarks.scaling --steps 4 \\
        --out benchmarks/results/BENCH_scaling.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_COUNTS = (1, 2, 4, 8)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DEFAULT_OUT = os.path.join(RESULTS_DIR, "BENCH_scaling.json")


def spec_for(devices: int, *, batch: int, seq: int, seed: int) -> dict:
    """The benchmarked TrainSpec per fleet size: model axis of 2 as soon as
    the fleet can afford one, remaining devices on data."""
    return {"reduced": True, "engine": "mesp", "optimizer": "sgd_momentum",
            "batch": batch, "seq": seq, "seed": seed,
            "model_parallel": 2 if devices >= 2 else 1}


def run_curve(counts=DEFAULT_COUNTS, *, steps: int = 4, batch: int = 4,
              seq: int = 32, seed: int = 7, verbose: bool = True) -> dict:
    from repro.launch.fleet import run_fleet

    rows = []
    for n in counts:
        spec = spec_for(n, batch=batch, seq=seq, seed=seed)
        train = run_fleet({"task": "train", "spec": spec, "steps": steps},
                          devices=n)
        coll = run_fleet({"task": "collectives", "spec": spec}, devices=n)
        row = {
            "devices": n,
            "mesh": train["mesh"],
            "model_parallel": spec["model_parallel"],
            "step_time_s": train["step_time_s"],
            "step_times_s": train["step_times_s"],
            "final_loss": train["losses"][-1],
            "collective_bytes": coll["collective_bytes"],
            "collective_bytes_total": sum(coll["collective_bytes"].values()),
            "n_trainable": coll["n_trainable"],
            "predicted_grad_sync_bytes": coll["predicted_grad_sync_bytes"],
        }
        rows.append(row)
        if verbose:
            print(f"devices={n:2d} mesh={row['mesh'] or '-'} "
                  f"step={row['step_time_s'] * 1e3:8.1f}ms "
                  f"coll={row['collective_bytes_total']:>9d}B "
                  f"grad_sync_floor={row['predicted_grad_sync_bytes']}B")
            sys.stdout.flush()
    base = rows[0]["step_time_s"]
    for row in rows:
        # overhead of running the same global problem on a larger emulated
        # fleet (shared CPU: >1 is expected; the gate bounds its growth)
        row["step_time_vs_1dev"] = row["step_time_s"] / base
    return {"setting": {"steps": steps, "batch": batch, "seq": seq,
                        "seed": seed, "arch": "reduced qwen2.5-0.5b",
                        "engine": "mesp"},
            "interpret": True,   # emulated CPU fleet, not accelerator perf
            "rows": rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--counts", type=int, nargs="+",
                    default=list(DEFAULT_COUNTS))
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    doc = run_curve(tuple(args.counts), steps=args.steps, batch=args.batch,
                    seq=args.seq)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
