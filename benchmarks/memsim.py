"""Analytical memory model under MLX retention semantics (paper Tables 1/2/4/5).

Why this exists: the paper measures ``phys_footprint`` of an MLX process on
an iPhone. Two platform behaviours dominate those numbers: (1) ``mx.grad``
retains every segment intermediate until backward consumes it, and (2)
allocator cache growth unless ``GPU.clearCache()`` is called per layer
(which is precisely what MeSP adds). XLA's static buffer assignment reuses
dead buffers automatically, so the XLA-measured peaks (benchmarks/memory.py)
show MeBP ≈ MeSP — the paper's mechanism is *already built into* XLA's
lifetime analysis (see EXPERIMENTS.md §Paper-repro discussion).

To reproduce the paper's *tables* we therefore model the retained-set
semantics the paper describes:

* **MeBP**  — all blocks' framework-retained intermediates live until their
  block's backward runs (paper §3.3 "implicitly determine which tensors to
  retain"), fused attention (no [N,N] probs retained).
* **MeSP**  — per-block outputs only (checkpoint dict), plus the E.1 stored
  subset and one block's recompute working set (paper §4.3-§4.4).
* **Store h** — MeSP + h=[B,N,r] stored for all 7·L LoRA layers (Table 5).
* **MeZO**  — inference working set + fp32 bookkeeping for the perturbed
  LoRA parameters (scales with rank — the paper's Table 4 observation).
  All ZO engines retain no activations, so the structured variants
  (``repro.zo``) resolve onto this model too — except:
* **MeZO sparse** — MeZO + the top-ρ |w| mask bookkeeping accounted
  explicitly: one byte per LoRA parameter while the probe's mask is alive
  (the mask is recomputed from |w| per probe, never persisted).

All terms are computed from tensor shapes (bf16 activations, fp32 softmax
statistics, 4-bit frozen weights with a bf16 dequant workspace). No
calibration constants are fit to the paper's numbers; agreement is assessed
in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs import get_config
from repro.configs.base import ArchConfig

BF16 = 2
F32 = 4
W4 = 0.5          # 4-bit quantized frozen weights
INT8 = 1          # int8 W0 (TPU path, core/quant.py)
RUNTIME_MB = 40.0  # process/runtime floor (Metal heap, code, tokenizer)


@dataclass
class Breakdown:
    weights_mb: float
    lora_mb: float
    activations_mb: float
    runtime_mb: float = RUNTIME_MB

    @property
    def total_mb(self) -> float:
        return (self.weights_mb + self.lora_mb + self.activations_mb +
                self.runtime_mb)


def _block_linear_params(cfg: ArchConfig) -> float:
    d, f = cfg.d_model, cfg.d_ff
    return (d * cfg.q_size + 2 * d * cfg.kv_size + cfg.q_size * d
            + 3 * d * f)


def _lora_params(cfg: ArchConfig, rank: int) -> float:
    d, f = cfg.d_model, cfg.d_ff
    per_block = rank * (
        (d + cfg.q_size) + 2 * (d + cfg.kv_size) + (cfg.q_size + d)
        + 2 * (d + f) + (f + d))
    return per_block * cfg.n_layers


def _dirty_weight_mb(cfg: ArchConfig) -> float:
    """4-bit weights are file-backed (mmap, mostly clean pages); the dirty
    set ≈ embedding rows touched + one dequantized matrix workspace."""
    dequant_ws = max(cfg.d_model * cfg.d_ff, cfg.d_model * cfg.q_size) * BF16
    touched_emb = cfg.vocab * cfg.d_model * W4 * 0.25
    return (dequant_ws + touched_emb) / 2**20


def _scale_count(cfg: ArchConfig) -> float:
    """Per-output-channel f32 scales for the int8 format (one per linear
    output column: q/k/v/o + gate/up/down per block)."""
    return (cfg.q_size + 2 * cfg.kv_size + cfg.d_model
            + 2 * cfg.d_ff + cfg.d_model) * cfg.n_layers


def resident_weight_mb(cfg: ArchConfig, fmt: str = "bf16") -> float:
    """HBM-resident frozen weights — the TPU accounting, where nothing is
    file-backed (contrast ``_dirty_weight_mb``'s mmap model).

    * ``bf16`` — dense W0 resident at 2 B/param.
    * ``int8`` — ``core/quant.py`` format: 1 B/param + f32 per-output-channel
      scales. No dequant workspace is charged: the pallas kernel path
      (``kernels/lora_quant.py``) dequantizes tile-wise in VMEM, never
      materializing a dense W0 in HBM.
    * ``int4`` / ``nf4`` — packed two-nibbles-per-byte format
      (``kernels/lora_pack4.py``): 0.5 B/param + the same f32 scale rows
      (the scale count is per output channel, independent of weight width).
      The nf4 16-entry codebook is 64 B per model — noise, not charged.

    Embeddings (and the untied head) stay bf16 in every format —
    ``quantize_frozen`` only rewrites ``w`` leaves.
    """
    lin = _block_linear_params(cfg) * cfg.n_layers
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if fmt == "bf16":
        return (lin + emb) * BF16 / 2**20
    if fmt == "int8":
        return (lin * INT8 + _scale_count(cfg) * F32 + emb * BF16) / 2**20
    if fmt in ("int4", "nf4"):
        return (lin * W4 + _scale_count(cfg) * F32 + emb * BF16) / 2**20
    raise ValueError(fmt)


def quantized_weight_ratio(cfg: ArchConfig, fmt: str = "bf16") -> float:
    """Resident bytes of the *quantizable* linear stack vs its bf16 bytes.

    ``resident_weight_mb`` ratios are diluted by the embeddings (and untied
    head), which stay bf16 in every format — on small-vocab-heavy models
    (0.5B: the tied embedding is ~30% of all params) the whole-model ratio
    floors well above the format's own compression. This isolates the bytes
    the format actually controls: ideal 0.5× for int8 and 0.25× for the
    packed 4-bit formats, plus the f32 scale rows (~2/d_model relative
    overhead). ``scripts/check_bench_regression.py --memory`` gates on it.
    """
    lin = _block_linear_params(cfg) * cfg.n_layers
    base = lin * BF16
    if fmt == "bf16":
        return 1.0
    if fmt == "int8":
        return (lin * INT8 + _scale_count(cfg) * F32) / base
    if fmt in ("int4", "nf4"):
        return (lin * W4 + _scale_count(cfg) * F32) / base
    raise ValueError(fmt)


def _per_block_intermediates(cfg: ArchConfig, B: int, N: int, rank: int,
                             with_h: bool = True) -> float:
    """Bytes mx.grad retains per transformer block (fused attention)."""
    d, f = cfg.d_model, cfg.d_ff
    t = 0.0
    t += 2 * B * N * d * BF16            # ln1/ln2 outputs
    t += B * N * (cfg.q_size + 2 * cfg.kv_size) * BF16   # q,k,v
    t += B * N * (cfg.q_size + 2 * cfg.kv_size) * BF16   # rope'd copies
    t += B * N * cfg.q_size * BF16       # attention output
    t += B * N * d * BF16                # o-proj output
    t += 3 * B * N * f * BF16            # gate, up, silu(gate)
    t += B * N * f * BF16                # gated product
    t += 2 * B * N * d * BF16            # down out + residual
    if with_h:
        t += 7 * B * N * rank * BF16     # LoRA h per projection
    return t


def _block_output(cfg: ArchConfig, B: int, N: int) -> float:
    return B * N * cfg.d_model * BF16 if False else B * N * cfg.d_model * BF16


def _head_working_set(cfg: ArchConfig, B: int, N: int) -> float:
    # logits bf16 + fp32 log-softmax statistics row-streamed (MLX fuses the
    # vocab softmax; retain one bf16 logits tensor)
    return B * N * cfg.vocab * BF16


def _mesp_stored_subset(cfg: ArchConfig, B: int, N: int) -> float:
    """Paper E.1: normalized input, attention weights (fused → row stats),
    pre-MLP normalized output, gate output — for ONE block."""
    d, f = cfg.d_model, cfg.d_ff
    return (2 * B * N * d + B * N * cfg.q_size + B * N * f) * BF16


#: retention models implemented below; engine names resolve onto one of
#: these via the registry's ``memsim`` hook (see ``_retention_model``)
RETENTION_MODELS = ("mebp", "mesp", "store_h", "mezo", "mezo_sparse")


def _retention_model(method: str) -> str:
    """Map an engine name to its analytical retention model: either one of
    RETENTION_MODELS directly, or any registered engine (its registration
    declares which model describes it — the registry's memory-sim hook)."""
    if method in RETENTION_MODELS:
        return method
    from repro.api import get_engine
    model = get_engine(method).memsim
    if model not in RETENTION_MODELS:
        raise ValueError(
            f"engine {method!r} declares memsim={model!r}, not one of "
            f"{RETENTION_MODELS}")
    return model


def simulate(arch: str, method: str, seq: int, batch: int = 1,
             rank: int = 8, weights_fmt: str | None = None,
             reduced: bool = False) -> Breakdown:
    """``method``: a retention model or any registered engine name.
    ``weights_fmt``: None reproduces the paper's phone setting (4-bit
    mmap'd weights, mostly clean pages); "bf16"/"int8" switch to the
    HBM-resident accounting (``resident_weight_mb``) used by the quantized
    column in paper_tables.md. ``reduced`` models the tiny same-family
    config instead (what CPU runs — and telemetry's measured-vs-predicted
    watermark cross-check — actually execute)."""
    method = _retention_model(method)
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    B, N, L = batch, seq, cfg.n_layers
    lora_mb = _lora_params(cfg, rank) * BF16 / 2**20
    weights_mb = (_dirty_weight_mb(cfg) if weights_fmt is None
                  else resident_weight_mb(cfg, weights_fmt))

    blk = _per_block_intermediates(cfg, B, N, rank)
    out = _block_output(cfg, B, N)
    head = _head_working_set(cfg, B, N)

    if method == "mebp":
        # all blocks' retained intermediates + head + grads(fp32 lora)
        acts = L * blk + L * out + head
        lora_mb += _lora_params(cfg, rank) * F32 / 2**20  # autodiff grads
    elif method == "mesp":
        # block outputs + E.1 subset + one block's recompute working set
        acts = L * out + _mesp_stored_subset(cfg, B, N) + blk + head
        lora_mb += _lora_params(cfg, rank) * F32 / 2**20 / L  # one block's
    elif method == "store_h":
        acts = (L * out + _mesp_stored_subset(cfg, B, N) + blk + head
                + L * 7 * B * N * rank * BF16)
        lora_mb += _lora_params(cfg, rank) * F32 / 2**20 / L
    elif method in ("mezo", "mezo_sparse"):
        # inference working set (one block transient + head) + fp32 z/update
        # bookkeeping over the perturbed LoRA params (×3: +z, −z, update)
        acts = blk + out + head
        lora_mb += 3 * _lora_params(cfg, rank) * F32 / 2**20
        if method == "mezo_sparse":
            # top-ρ |w| mask: boolean, one byte per LoRA param while a
            # probe is live (the f32 |w| quantile scratch is per-leaf
            # transient inside the probe working set, not retained)
            lora_mb += _lora_params(cfg, rank) * 1 / 2**20
    else:
        raise ValueError(method)

    return Breakdown(weights_mb=weights_mb, lora_mb=lora_mb,
                     activations_mb=acts / 2**20)


def kv_page_mb(cfg: ArchConfig, page_size: int) -> float:
    """One KV page (``page_size`` token positions, k+v, every layer) in MB.
    Matches the per-slot dense cache layout (bf16 k/v over
    ``n_layers × n_kv_heads × head_dim``)."""
    hd = cfg.resolved_head_dim
    return (2 * cfg.n_layers * cfg.n_kv_heads * hd * page_size * BF16) / 2**20


def adapter_slot_mb(cfg: ArchConfig, rank: int) -> float:
    """One resident tenant's stacked (A, B) leaves in MB (AdapterStore)."""
    return _lora_params(cfg, rank) * BF16 / 2**20


def serve_residency(cfg, *, rank: int, resident_adapters: int,
                    kv_pages: int, page_size: int, batch: int = 1,
                    weights_fmt: str = "bf16") -> dict:
    """Serve-side resident-set accounting (MB breakdown + total).

    Terms: base weights HBM-resident (``resident_weight_mb`` — bf16 or the
    int8 format), the AdapterStore's resident tenants (``resident_adapters``
    × one stacked (A, B) set at ``rank``), live KV pages (the paged
    allocator's reserved pages), and the decode working set (one block's
    transient intermediates at N=1 plus the logits head, for ``batch``
    concurrent rows). The continuous batcher's admission headroom check and
    the ``serving`` table in ``benchmarks/run.py`` both consume this.
    """
    if isinstance(cfg, str):
        cfg = get_config(cfg)
    weights_mb = resident_weight_mb(cfg, weights_fmt)
    adapters_mb = resident_adapters * adapter_slot_mb(cfg, rank)
    kv_mb = kv_pages * kv_page_mb(cfg, page_size)
    decode_mb = (_per_block_intermediates(cfg, batch, 1, rank)
                 + _head_working_set(cfg, batch, 1)) / 2**20
    total = weights_mb + adapters_mb + kv_mb + decode_mb + RUNTIME_MB
    return {"weights_mb": weights_mb, "adapters_mb": adapters_mb,
            "kv_mb": kv_mb, "decode_mb": decode_mb,
            "runtime_mb": RUNTIME_MB, "total_mb": total}


def table(models, methods, seq: int = 256, rank: int = 8):
    rows = []
    for m in models:
        for meth in methods:
            b = simulate(m, meth, seq, rank=rank)
            rows.append((m, meth, b.total_mb))
    return rows
