"""AOT-compiled memory/FLOPs measurement for the paper's tables.

``phys_footprint`` on an iPhone is not measurable here; the TPU-world
equivalent is XLA's static allocation plan: ``compiled.memory_analysis()``.
We report

* ``temp_mb``  — peak temporary (activation/workspace) bytes: the quantity
  MeSP optimizes (weights are identical across methods),
* ``arg_mb``   — parameter+input bytes (same for all methods),
* ``flops``    — trip-count-corrected HLO FLOPs (compute-overhead column).

Everything is compiled against ShapeDtypeStructs — the 0.5B–3B paper models
are never materialized on this CPU.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api import ExecutionPolicy, get_engine
from repro.configs import get_config
from repro.configs.base import ArchConfig, LoRAConfig
from repro.models import model as model_lib
from repro.roofline.hlo_parse import analyze_text

_CACHE_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "_memory_cache.json")


def _cache():
    if os.path.exists(_CACHE_PATH):
        with open(_CACHE_PATH) as f:
            return json.load(f)
    return {}


def _save_cache(c):
    os.makedirs(os.path.dirname(_CACHE_PATH), exist_ok=True)
    with open(_CACHE_PATH, "w") as f:
        json.dump(c, f, indent=1)


def with_rank(cfg: ArchConfig, rank: int) -> ArchConfig:
    return dataclasses.replace(
        cfg, lora=LoRAConfig(rank=rank, alpha=16.0, targets=cfg.lora.targets))


def measure(arch: str, engine: str, seq: int, batch: int = 1,
            rank: int = 8, use_cache: bool = True,
            quantize: Optional[str] = None) -> dict:
    """Compile one train step on a single abstract device; return metrics.

    engine: any registered engine name (``repro.api.engine_names()``); the
    step is built from the registration's ``value_and_grad`` hook, so a
    newly registered engine is measurable with no edits here.
    quantize: None or a ``core.quant.METHODS`` entry — "int8" holds frozen
    base weights as {q, scale} leaves (weight bytes halve); packed
    "int4"/"nf4" hold them as {q4, scale, ...} nibble-packed leaves (weight
    bytes quarter). Shows up in ``arg_mb`` and, on non-pallas engines, in
    ``temp_mb`` via the dequant workspaces.
    """
    key = f"{arch}|{engine}|{seq}|{batch}|r{rank}" + \
        (f"|{quantize}" if quantize else "")
    cache = _cache()
    if use_cache and key in cache:
        return cache[key]

    cfg = with_rank(get_config(arch), rank)
    pstruct = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg,
                                      quantize=quantize))
    bstruct = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }

    lr = 1e-4
    eng = get_engine(engine)
    if eng.value_and_grad is None:
        raise ValueError(
            f"engine {engine!r} declares no value_and_grad hook; register "
            f"it with value_and_grad=... to make it AOT-measurable (or "
            f"benchmark=False to keep it out of the sweep)")
    policy = ExecutionPolicy(backend=eng.backend or "plain",
                             quantize=quantize or "none")

    def step(params, batch):
        loss, grads = eng.value_and_grad(params, cfg, batch, policy=policy,
                                         key=jax.random.PRNGKey(0))
        new = jax.tree_util.tree_map(
            lambda p, g: p if g is None else (p - lr * g.astype(p.dtype)),
            params, grads, is_leaf=lambda x: x is None)
        return new, loss

    compiled = jax.jit(step).lower(pstruct, bstruct).compile()
    ma = compiled.memory_analysis()
    tot = analyze_text(compiled.as_text())
    out = {
        "temp_mb": ma.temp_size_in_bytes / 2**20,
        "arg_mb": ma.argument_size_in_bytes / 2**20,
        "flops": tot.flops,
        "bytes": tot.bytes,
    }
    cache[key] = out
    _save_cache(cache)
    return out
