"""AOT-compiled memory/FLOPs measurement for the paper's tables.

``phys_footprint`` on an iPhone is not measurable here; the TPU-world
equivalent is XLA's static allocation plan: ``compiled.memory_analysis()``.
We report

* ``temp_mb``  — peak temporary (activation/workspace) bytes: the quantity
  MeSP optimizes (weights are identical across methods),
* ``arg_mb``   — parameter+input bytes (same for all methods),
* ``flops``    — trip-count-corrected HLO FLOPs (compute-overhead column).

Everything is compiled against ShapeDtypeStructs — the 0.5B–3B paper models
are never materialized on this CPU.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ArchConfig, LoRAConfig
from repro.core import mesp, mezo
from repro.models import model as model_lib
from repro.roofline.hlo_parse import analyze_text

_CACHE_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "_memory_cache.json")


def _cache():
    if os.path.exists(_CACHE_PATH):
        with open(_CACHE_PATH) as f:
            return json.load(f)
    return {}


def _save_cache(c):
    os.makedirs(os.path.dirname(_CACHE_PATH), exist_ok=True)
    with open(_CACHE_PATH, "w") as f:
        json.dump(c, f, indent=1)


def with_rank(cfg: ArchConfig, rank: int) -> ArchConfig:
    return dataclasses.replace(
        cfg, lora=LoRAConfig(rank=rank, alpha=16.0, targets=cfg.lora.targets))


def measure(arch: str, engine: str, seq: int, batch: int = 1,
            rank: int = 8, use_cache: bool = True,
            quantize: Optional[str] = None) -> dict:
    """Compile one train step on a single abstract device; return metrics.

    engine: mesp | mesp_pallas | mebp | store_h | mezo
    quantize: None | "int8" — frozen base weights held as {q, scale} leaves;
    shows up in ``arg_mb`` (weight bytes halve) and, on non-pallas engines,
    in ``temp_mb`` via the dequant workspaces.
    """
    key = f"{arch}|{engine}|{seq}|{batch}|r{rank}" + \
        (f"|{quantize}" if quantize else "")
    cache = _cache()
    if use_cache and key in cache:
        return cache[key]

    cfg = with_rank(get_config(arch), rank)
    pstruct = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg,
                                      quantize=quantize))
    bstruct = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }

    lr = 1e-4
    if engine == "mezo":
        def step(params, batch):
            loss, grads = mezo.spsa_grad(params, cfg, batch,
                                         jax.random.PRNGKey(0))
            new = jax.tree_util.tree_map(
                lambda p, g: p - lr * g, *model_lib.split_params(params)[:1],
                grads)
            return model_lib.merge_params(
                new, model_lib.split_params(params)[1]), loss
    else:
        mode = {"mesp": "structured", "mesp_pallas": "pallas",
                "mebp": "plain", "store_h": "store_h"}[engine]

        def step(params, batch):
            return mesp.train_step(params, cfg, batch, lr, mode=mode)

    compiled = jax.jit(step).lower(pstruct, bstruct).compile()
    ma = compiled.memory_analysis()
    tot = analyze_text(compiled.as_text())
    out = {
        "temp_mb": ma.temp_size_in_bytes / 2**20,
        "arg_mb": ma.argument_size_in_bytes / 2**20,
        "flops": tot.flops,
        "bytes": tot.bytes,
    }
    cache[key] = out
    _save_cache(cache)
    return out
