"""Multi-tenant serving benchmark — tokens/s and schedule accounting for
the grouped-LoRA continuous-batching path.

    PYTHONPATH=src python -m benchmarks.serving [--out PATH] [--fast]

Three sections, written to ``benchmarks/results/BENCH_serving.json``:

* ``continuous`` — end-to-end served tokens/s of the
  :class:`repro.serve.ContinuousBatcher` on a reduced dense config, same
  request trace with 8 tenant adapters vs a single tenant (the multi-tenant
  cost of adapter routing + store churn), plus the full admission /
  eviction / page counters. Timed after a synced, discarded warmup run.
* ``grouped_kernel`` — one grouped-kernel launch
  (``kernels/lora_grouped.py``) vs the per-adapter Python loop it replaces
  (slice rows per adapter, dense matmul + 2-D LoRA each), on a ragged
  multi-tenant row layout; carries the *deterministic* trace-time schedule
  stats (``tiling.grouped_schedule_stats``: live vs dense tiles, grid
  fraction) that ``scripts/check_bench_regression.py --serving`` gates.
* ``memsim`` — the analytic serve-residency breakdown for the benchmark
  setting (``benchmarks/memsim.serve_residency``).

Wall-clock columns are annotation-only off-TPU (``interpret: true``): the
Pallas interpreter measures emulation cost, not hardware — the schedule
stats and counters are the host-independent columns.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DEFAULT_OUT = os.path.join(RESULTS_DIR, "BENCH_serving.json")

SETTING = {"arch": "qwen2.5-0.5b", "reduced": True, "slots": 16, "tile": 2,
           "adapters": 8, "capacity": 4, "requests": 16, "prompt_len": 3,
           "max_new": 6, "max_len": 32, "page_size": 8, "rank": None}


def _trace(n, uids, prompt_len, max_new):
    from repro.serve import Request
    return [Request(f"r{i}", uids[i % len(uids)],
                    tuple(1 + (3 * i + j) % 97 for j in range(prompt_len)),
                    max_new) for i in range(n)]


def _run_continuous(cfg, params, n_adapters: int, s: dict) -> dict:
    from repro.serve import AdapterStore, ContinuousBatcher, Request, \
        synthetic_adapters
    store = AdapterStore(params, capacity=min(s["capacity"], n_adapters))
    bat = ContinuousBatcher(cfg, store, slots=s["slots"], tile=s["tile"],
                            max_len=s["max_len"], page_size=s["page_size"])
    uids = [f"tenant{i}" for i in range(n_adapters)]
    for i, uid in enumerate(uids):
        bat.register_adapter(uid, synthetic_adapters(params, i))
    # warmup: compile the decode step, then reset every counter (discarded)
    bat.run([Request("warmup", uids[0], (1, 2, 3), 2)])
    for c in (bat.counters, store.counters, bat.alloc.counters):
        c.update({k: 0 for k in c})
    bat.results.clear()

    reqs = _trace(s["requests"], uids, s["prompt_len"], s["max_new"])
    t0 = time.perf_counter()
    results = bat.run(reqs)
    jax.block_until_ready(bat.cache)
    dt = time.perf_counter() - t0
    served = sum(len(v) for v in results.values())
    return {"adapters": n_adapters, "served_tokens": served,
            "completed": len(results), "elapsed_s": dt,
            "tokens_per_s": served / dt, "counters": dict(bat.counters),
            "store": dict(store.counters),
            "pages": dict(bat.alloc.counters),
            # unified namespaced registry view (serve.* / store.* / pages.*)
            # — same numbers as the three dicts above, one flat snapshot
            "metrics": bat.metrics(),
            "store_slot_mb": store.slot_bytes / 2**20}


def bench_continuous(s: dict) -> dict:
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config(s["arch"]).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    multi = _run_continuous(cfg, params, s["adapters"], s)
    single = _run_continuous(cfg, params, 1, s)
    return {"multi": multi, "single": single,
            "multi_over_single": multi["elapsed_s"] / single["elapsed_s"]}


def bench_grouped_kernel() -> dict:
    """One grouped launch vs the per-adapter slice-and-matmul loop, on a
    ragged tenant layout (some tenants idle — the schedule skips their
    tiles; that skip is what the regression gate pins)."""
    from repro.kernels import ops, tiling
    from repro.kernels.lora_grouped import lora_grouped

    interp = ops.pallas_interpret()
    E, K, N, r, bm = 8, 64, 64, 8, 8
    sizes = (8, 0, 16, 8, 0, 24, 0, 8)          # ragged; 3 idle tenants
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xs = [jax.random.normal(ks[0], (c, K), jnp.float32) for c in sizes]
    w0 = jax.random.normal(ks[1], (1, K, N), jnp.float32) * 0.1
    a = jax.random.normal(ks[2], (E, K, r), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (E, r, N), jnp.float32) * 0.1

    gid, _ = tiling.grouped_schedule(sizes, bm)
    xp = tiling.pack_ragged_rows(jnp.concatenate(xs), sizes, bm)

    grouped = jax.jit(lambda x: lora_grouped(
        x, w0, a, b, jnp.asarray(gid), 2.0, bm=bm, bn=N, bk=K,
        interpret=interp))

    def loop(xs):
        return [x @ w0[0] + 2.0 * ((x @ a[g]) @ b[g])
                for g, x in enumerate(xs) if x.shape[0]]

    loop_j = jax.jit(loop)

    def _time(fn, *args, repeats=3):
        jax.block_until_ready(fn(*args))        # compile — never timed
        best = float("inf")
        for _ in range(repeats + 1):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    g_ms = _time(grouped, xp) * 1e3
    l_ms = _time(loop_j, xs) * 1e3
    # equivalence of the two comparators (the benchmark is only honest if
    # they compute the same thing)
    got = tiling.unpack_ragged_rows(grouped(xp), sizes, bm)
    ref = jnp.concatenate(loop_j(xs))
    err = float(jnp.max(jnp.abs(got - ref)))
    stats = tiling.grouped_schedule_stats(sizes, bm)
    return {"shape": {"E": E, "K": K, "N": N, "r": r, "bm": bm,
                      "group_sizes": list(sizes)},
            "grouped_ms": g_ms, "loop_ms": l_ms,
            "loop_over_grouped": l_ms / g_ms, "max_abs_err": err,
            "schedule": stats}


def run_and_write(out: str = DEFAULT_OUT, setting: dict | None = None) -> dict:
    from benchmarks import memsim
    from repro.configs import get_config
    from repro.kernels import ops

    s = dict(SETTING, **(setting or {}))
    cfg = get_config(s["arch"]).reduced()
    s["rank"] = cfg.lora.rank
    interp = ops.pallas_interpret()
    cont = bench_continuous(s)
    gk = bench_grouped_kernel()
    sim = memsim.serve_residency(
        cfg, rank=cfg.lora.rank, resident_adapters=s["capacity"],
        kv_pages=s["slots"] * s["max_len"] // s["page_size"],
        page_size=s["page_size"], batch=s["slots"])
    result = {
        "backend": jax.default_backend(),
        "interpret": interp,
        "note": ("interpret mode: wall-clock measures the Pallas emulation, "
                 "not TPU perf") if interp else "compiled TPU kernels",
        "setting": s,
        "continuous": cont,
        "grouped_kernel": gk,
        "memsim": sim,
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--adapters", type=int, default=None,
                    help="override tenant count (default from SETTING)")
    args = ap.parse_args(argv)
    over = {} if args.adapters is None else {"adapters": args.adapters}
    result = run_and_write(args.out, over)
    print(json.dumps(result, indent=1, sort_keys=True))
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
