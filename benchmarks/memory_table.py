"""Committed HBM-residency tables -> BENCH_memory.json (the CI memory gate).

Pure-analytic counterpart of ``benchmarks/serving.py``'s ``run_and_write``:
every number comes from ``benchmarks/memsim``'s shape arithmetic — no jax
compute, no wall-clock, no interpreter caveats — so the committed table is
bit-reproducible on any host and ``scripts/check_bench_regression.py
--memory`` can gate it hard.

Three sections per run:

* ``models``  — per paper model: ``resident_weight_mb`` for every weights
  format ``core/quant.weights_format`` knows (bf16 / int8 / packed int4 /
  nf4), the ratio of each vs bf16 (the figures the gate's 0.55×/0.30×
  ceilings check), and the MeSP train-peak total per format;
* ``serving`` — the serve-side residency split (``memsim.serve_residency``)
  per format at the BENCH_serving.json setting, showing how the packed
  formats move the weights/adapters/KV balance of the resident set;
* ``formats`` — the swept format list, generated from ``core.quant.METHODS``
  so a newly registered quantize method joins the table (and the gate) with
  zero edits here.

    PYTHONPATH=src python -m benchmarks.memory_table
    PYTHONPATH=src python scripts/check_bench_regression.py \\
        --memory benchmarks/results/BENCH_memory.json
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks import memsim
from repro.configs import get_config
from repro.core import quant

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DEFAULT_OUT = os.path.join(RESULTS_DIR, "BENCH_memory.json")

MODELS = ("qwen2.5-0.5b", "qwen2.5-1.5b", "qwen2.5-3b")

#: mirrors benchmarks/serving.py's SETTING (capacity/slots/max_len/page_size)
SERVE = {"arch": "qwen2.5-0.5b", "rank": 8, "resident_adapters": 4,
         "slots": 4, "max_len": 128, "page_size": 16}


def build(models=MODELS, seq: int = 256) -> dict:
    fmts = [quant.weights_format(m) for m in quant.METHODS]  # bf16 first
    rows = {}
    for arch in models:
        cfg = get_config(arch)
        w = {f: memsim.resident_weight_mb(cfg, f) for f in fmts}
        rows[arch] = {
            "resident_weight_mb": w,
            "ratio_vs_bf16": {f: w[f] / w["bf16"] for f in fmts[1:]},
            # embedding-free ratio over the bytes the format controls — the
            # column the --memory gate's 0.55x/0.30x ceilings check
            "quantized_ratio_vs_bf16": {
                f: memsim.quantized_weight_ratio(cfg, f)
                for f in fmts[1:]},
            "mesp_total_mb": {
                f: memsim.simulate(arch, "mesp", seq,
                                   weights_fmt=f).total_mb
                for f in fmts},
        }
    serve = {
        f: memsim.serve_residency(
            SERVE["arch"], rank=SERVE["rank"],
            resident_adapters=SERVE["resident_adapters"],
            kv_pages=SERVE["slots"] * SERVE["max_len"] // SERVE["page_size"],
            page_size=SERVE["page_size"], batch=SERVE["slots"],
            weights_fmt=f)
        for f in fmts}
    return {"formats": fmts, "seq": seq, "models": rows,
            "serving": {"setting": dict(SERVE), "residency": serve}}


def run_and_write(out: str = DEFAULT_OUT) -> dict:
    result = build()
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    result = run_and_write(args.out)
    for arch, row in result["models"].items():
        ratios = " ".join(f"{f}={r:.3f}"
                          for f, r in sorted(row["ratio_vs_bf16"].items()))
        print(f"{arch}: W0 bf16 "
              f"{row['resident_weight_mb']['bf16']:.1f} MB; ratios {ratios}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
