"""Chaos benchmark: what resilience costs, measured.

Runs the same reduced fine-tune twice through the ``repro.api.Trainer``
facade — once fault-free, once with a deterministic 5-fault chaos plan
(OOM, checkpoint corruption, process crash, NaN loss, straggler stall at
five distinct steps) — and reports what recovery cost:

* **steps_to_recover** — steps replayed after restore rewinds;
* **degradations** — ladder rungs applied (the OOM lands the run on a
  memsim-validated cheaper spec);
* **recovery_overhead_pct** — extra wall-clock of the chaos run over the
  fault-free run (includes backoff, re-jits, replays and the stall itself);
* **loss_delta** — |final chaos loss − final fault-free loss|: the chaos
  run must land in the same place, not merely finish.

    PYTHONPATH=src python -m benchmarks.resilience            # full
    PYTHONPATH=src python -m benchmarks.resilience --smoke    # CI

Writes ``BENCH_resilience.json`` (committed baseline under
``benchmarks/results/``; ``scripts/check_bench_regression.py --resilience``
annotates drift against it — never gated, wall-clock depends on the host).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import tempfile
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BASELINE = os.path.join(RESULTS_DIR, "BENCH_resilience.json")

#: full setting: 24 steps, faults at 5 distinct steps covering every kind
FULL = dict(steps=24, seq=64, batch=2, ckpt_interval=5,
            plan="oom@4,corrupt@8,crash@9,nan@14,stall@18:1.2")
#: CI smoke: same machinery, ~1/2 the steps and a shorter stall
#: (crash lands between interval saves, so the restore must fall back over
#: the checkpoint the corrupt event poisoned)
SMOKE = dict(steps=12, seq=48, batch=2, ckpt_interval=3,
             plan="oom@2,corrupt@4,crash@5,nan@8,stall@10:0.8")


def _fit(spec):
    from repro.api import Trainer

    t0 = time.monotonic()
    result = Trainer.from_spec(spec).fit()
    return result, time.monotonic() - t0


def run(smoke: bool = False, arch: str = "qwen2.5-0.5b",
        seed: int = 0) -> dict:
    import jax

    from repro.api import TrainSpec
    from repro.runtime.degrade import predicted_peak_mb

    setting = SMOKE if smoke else FULL
    workdir = tempfile.mkdtemp(prefix="bench_resilience_")
    base = TrainSpec(
        arch=arch, reduced=True, engine="mesp", seed=seed,
        steps=setting["steps"], seq=setting["seq"], batch=setting["batch"],
        ckpt_interval=setting["ckpt_interval"],
        ckpt_dir=os.path.join(workdir, "baseline"),
        # one stalled step must trigger the supervised restart path
        straggler_factor=8.0, straggler_limit=1)
    try:
        import dataclasses
        clean, clean_s = _fit(base)
        chaos_spec = dataclasses.replace(
            base, ckpt_dir=os.path.join(workdir, "chaos"),
            inject_faults=setting["plan"])
        chaos, chaos_s = _fit(chaos_spec)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    counters = chaos.fault_counts
    fs = chaos.final_spec
    doc = {
        "benchmark": "resilience",
        "setting": {**setting, "arch": arch, "seed": seed, "smoke": smoke},
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "fault_free": {
            "wall_s": round(clean_s, 3),
            "steps": len(clean.history),
            "final_loss": round(clean.final_loss, 6),
        },
        "chaos": {
            "wall_s": round(chaos_s, 3),
            "steps_executed": len(chaos.history),
            "final_loss": round(chaos.final_loss, 6),
            "counters": counters,
            "degradations": chaos.degradations,
            "final_spec": {"engine": fs.engine, "batch": fs.batch,
                           "seq": fs.seq, "quantize": fs.quantize},
            "final_predicted_peak_mb": predicted_peak_mb(fs),
            # StepGuard EWMA state + per-reason rejection counts
            # (TrainResult.metrics["guard"], telemetry PR)
            "guard": chaos.metrics.get("guard", {}),
        },
        "metrics": {
            "steps_to_recover": counters.get("steps_replayed", 0),
            "degradation_events": len(chaos.degradations),
            "recovery_overhead_pct": round(
                100.0 * (chaos_s - clean_s) / clean_s, 1),
            "loss_delta": round(abs(chaos.final_loss - clean.final_loss), 6),
        },
    }
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI setting: fewer steps, shorter stall")
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=BASELINE,
                    help="output JSON path (default: the committed baseline)")
    args = ap.parse_args(argv)

    doc = run(smoke=args.smoke, arch=args.arch, seed=args.seed)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")

    m, c = doc["metrics"], doc["chaos"]
    print(f"resilience: {doc['fault_free']['steps']} fault-free steps "
          f"{doc['fault_free']['wall_s']}s; chaos survived "
          f"{sum(c['counters'].get('injected', {}).values())} injected "
          f"faults in {c['wall_s']}s")
    print(f"  steps_to_recover={m['steps_to_recover']} "
          f"degradations={c['degradations']} "
          f"recovery_overhead={m['recovery_overhead_pct']}% "
          f"loss_delta={m['loss_delta']}")
    print(f"  final spec: {c['final_spec']} "
          f"(predicted peak {c['final_predicted_peak_mb']} MB)")
    g = c.get("guard") or {}
    if g:
        print(f"  guard: accepted={g.get('accepted')} "
          f"rejected={g.get('rejected')} by_reason="
          f"{ {k: v for k, v in (g.get('by_reason') or {}).items() if v} }")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
