"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run             # all tables
    PYTHONPATH=src python -m benchmarks.run --only t1 t3

Outputs ``name,value,derived`` CSV lines to stdout and a markdown report to
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

PAPER_MODELS = ["qwen2.5-0.5b", "qwen2.5-1.5b", "qwen2.5-3b"]


def _engines():
    """Benchmark sweep list, generated from the engine registry: every
    registration with ``benchmark=True`` and a ``value_and_grad`` hook (the
    hook is what ``benchmarks/memory.py`` AOT-measures, so it is the price
    of admission; a newly registered engine declaring one joins the sweep
    automatically). ``mebp`` is the reduction baseline."""
    from repro.api import list_engines
    return [e.name for e in list_engines()
            if e.benchmark and e.value_and_grad is not None]


ENGINES = _engines()

_report_lines = []


def emit(name, value, derived=""):
    print(f"{name},{value},{derived}")
    sys.stdout.flush()


def report(line=""):
    _report_lines.append(line)


# --------------------------------------------------------------------- T1
PAPER_T1 = {("qwen2.5-0.5b", "mebp"): 360.8, ("qwen2.5-0.5b", "mezo"): 243.0,
            ("qwen2.5-0.5b", "mesp"): 136.2, ("qwen2.5-1.5b", "mebp"): 516.2,
            ("qwen2.5-1.5b", "mezo"): 376.0, ("qwen2.5-1.5b", "mesp"): 262.6,
            ("qwen2.5-3b", "mebp"): 637.6, ("qwen2.5-3b", "mezo"): 479.2,
            ("qwen2.5-3b", "mesp"): 368.4}


def table1():
    """Memory & compute per method across model sizes (paper Table 1).

    Two measurements: (a) the MLX-retention-semantics simulator (reproduces
    the paper's phys_footprint setting), (b) XLA static peak-temp from AOT
    compilation (the TPU-platform answer — see EXPERIMENTS.md discussion).
    """
    from benchmarks.memory import measure
    from benchmarks.memsim import simulate
    report("## Table 1 — memory per method, seq 256, batch 1")
    report("| model | method | sim MB | paper MB | sim red. | paper red. "
           "| XLA temp MB | HLO FLOPs |")
    report("|---|---|---|---|---|---|---|---|")
    for arch in PAPER_MODELS:
        sims = {e: simulate(arch, e, 256).total_mb for e in ENGINES}
        base_sim = sims["mebp"]
        base_paper = PAPER_T1[(arch, "mebp")]
        for engine in ENGINES:
            sim = sims[engine]
            paper = PAPER_T1.get((arch, engine))  # engines beyond the
            # paper's three have no reference column
            m = measure(arch, engine, seq=256)
            red_s = 1 - sim / base_sim
            paper_s = paper if paper is not None else "—"
            red_p = (f"{1 - paper / base_paper:.0%}" if paper is not None
                     else "—")
            emit(f"t1/{arch}/{engine}/sim_mb", f"{sim:.1f}",
                 f"paper={paper_s} xla_temp={m['temp_mb']:.0f}")
            report(f"| {arch} | {engine} | {sim:.0f} | {paper_s} | "
                   f"{red_s:.0%} | {red_p} | {m['temp_mb']:.0f} | "
                   f"{m['flops']:.3g} |")


# --------------------------------------------------------------------- T2
def table2():
    """Memory vs sequence length, qwen2.5-0.5b (paper Table 2 + appx B)."""
    from benchmarks.memory import measure
    report("\n## Table 2 — peak temp memory (MB) vs sequence length "
           "(qwen2.5-0.5b)")
    from benchmarks.memsim import simulate
    seqs = [128, 256, 512, 1024]
    report("| method | " + " | ".join(map(str, seqs)) +
           " | (sim MB; paper: MeBP 253/361/582/1050, MeSP 111/136/246/514)|")
    report("|---|" + "---|" * (len(seqs) + 1))
    rows = {}
    for engine in ENGINES:
        vals = [simulate("qwen2.5-0.5b", engine, s).total_mb for s in seqs]
        xla = [measure("qwen2.5-0.5b", engine, seq=s)["temp_mb"]
               for s in seqs]
        rows[engine] = vals
        for s, v, x in zip(seqs, vals, xla):
            emit(f"t2/{engine}/seq{s}/sim_mb", f"{v:.1f}",
                 f"xla_temp={x:.0f}")
        report(f"| {engine} | " + " | ".join(f"{v:.0f}" for v in vals)
               + " | |")
    for engine in ("mezo", "mesp"):
        reds = [1 - a / b for a, b in zip(rows[engine], rows["mebp"])]
        report(f"| {engine} red. | " +
               " | ".join(f"{r:.0%}" for r in reds) + " | |")


# --------------------------------------------------------------------- T3
def table3():
    """MeZO gradient quality vs exact gradients (paper Table 3)."""
    from repro.configs import get_config
    from repro.core import gradcheck, mesp, mezo
    from repro.models import model as M

    report("\n## Table 3 — MeZO gradient quality vs exact (reduced "
           "qwen2.5-0.5b family model, real computation)")
    cfg = get_config("qwen2.5-0.5b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=6)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    # a few warm-up steps so LoRA B ≠ 0 (at init dL/dA ≡ 0 exactly, which
    # would degenerate the sign-agreement statistic)
    for _ in range(5):
        params, _ = mesp.train_step(params, cfg, batch, 5e-2)
    _, g_true = mesp.value_and_grad(params, cfg, batch)
    _, g_est = mezo.spsa_grad(params, cfg, batch, jax.random.PRNGKey(2))
    rows = gradcheck.per_layer_metrics(g_est["blocks"], g_true["blocks"],
                                       cfg.n_layers)
    report("| layer | cosine sim | sign agree | rel. error |")
    report("|---|---|---|---|")
    for r in rows:
        emit(f"t3/layer{r['layer']}/cosine", f"{r['cosine_sim']:.4f}",
             f"sign={r['sign_agree']:.3f}")
        report(f"| {r['layer']} | {r['cosine_sim']:.4f} | "
               f"{r['sign_agree']:.1%} | {r['rel_error']:.1f} |")
    avg = {k: float(np.mean([r[k] for r in rows]))
           for k in ("cosine_sim", "sign_agree", "rel_error")}
    emit("t3/avg/cosine", f"{avg['cosine_sim']:.4f}",
         f"sign={avg['sign_agree']:.3f}")
    report(f"| avg | {avg['cosine_sim']:.4f} | {avg['sign_agree']:.1%} | "
           f"{avg['rel_error']:.1f} |")


# --------------------------------------------------------------------- T4
def table4():
    """Memory vs LoRA rank (paper Table 4)."""
    from benchmarks.memory import measure
    report("\n## Table 4 — peak temp memory (MB) vs LoRA rank "
           "(qwen2.5-0.5b, seq 256)")
    from benchmarks.memsim import simulate
    ranks = [4, 8, 16, 32]
    report("| method | " + " | ".join(f"r={r}" for r in ranks) +
           " | (sim MB; paper MeSP 133/136/144/158, MeZO 215/243/299/411) |")
    report("|---|" + "---|" * (len(ranks) + 1))
    rows = {}
    for engine in ENGINES:
        vals = [simulate("qwen2.5-0.5b", engine, 256, rank=r).total_mb
                for r in ranks]
        rows[engine] = vals
        for r, v in zip(ranks, vals):
            emit(f"t4/{engine}/rank{r}/sim_mb", f"{v:.1f}")
        report(f"| {engine} | " + " | ".join(f"{v:.0f}" for v in vals)
               + " | |")
    for engine in ("mezo", "mesp"):
        reds = [1 - a / b for a, b in zip(rows[engine], rows["mebp"])]
        report(f"| {engine} red. | " +
               " | ".join(f"{r:.0%}" for r in reds) + " | |")


# --------------------------------------------------------------------- T5
def table5():
    """Store-h vs recompute-h ablation (paper Table 5, qwen2.5-3b seq 256)."""
    from benchmarks.memory import measure
    from benchmarks.memsim import simulate
    report("\n## Table 5 — h strategy ablation (qwen2.5-3b, seq 256; "
           "paper 637.6 / 398.5 / 368.4 MB)")
    report("| strategy | sim MB | XLA temp MB | HLO FLOPs |")
    report("|---|---|---|---|")
    for engine, label in (("mebp", "MeBP (baseline)"),
                          ("store_h", "Store h"),
                          ("mesp", "Recompute h (ours)")):
        sim = simulate("qwen2.5-3b", engine, 256).total_mb
        m = measure("qwen2.5-3b", engine, seq=256)
        emit(f"t5/{engine}/sim_mb", f"{sim:.1f}",
             f"xla_temp={m['temp_mb']:.0f} flops={m['flops']:.3g}")
        report(f"| {label} | {sim:.0f} | {m['temp_mb']:.0f} | "
               f"{m['flops']:.3g} |")


# ------------------------------------------------------------------- Fig 2
def figure2(steps: int = 300):
    """Convergence: MeSP ≡ MeBP, MeZO behind (paper Fig. 2 / Table 11)."""
    from repro.configs import get_config
    from repro.core import mebp, mesp, mezo
    from repro.data import make_batch_iterator
    from repro.models import model as M

    report("\n## Figure 2 — convergence on the reduced model "
           f"({steps} steps, synthetic Zipf corpus)")
    cfg = get_config("qwen2.5-0.5b").reduced()
    params0 = M.init_params(jax.random.PRNGKey(0), cfg)

    def run(engine):
        it = make_batch_iterator(cfg.vocab, 64, 4, n_tokens=1 << 16, seed=7)
        p = params0
        s_mesp = jax.jit(lambda p, b: mesp.train_step(p, cfg, b, 5e-2))
        s_mebp = jax.jit(lambda p, b: mebp.train_step(p, cfg, b, 5e-2))
        losses = []
        for i in range(steps):
            b = next(it)
            if engine == "mesp":
                p, l = s_mesp(p, b)
            elif engine == "mebp":
                p, l = s_mebp(p, b)
            else:
                p, l = mezo.train_step(p, cfg, b, jax.random.PRNGKey(i), 5e-3)
            losses.append(float(l))
        return losses

    t0 = time.monotonic()
    curves = {e: run(e) for e in ("mebp", "mesp", "mezo")}
    report("| step | MeBP | MeSP | MeZO |")
    report("|---|---|---|---|")
    for i in range(0, steps, max(1, steps // 10)):
        report(f"| {i} | {curves['mebp'][i]:.4f} | {curves['mesp'][i]:.4f} "
               f"| {curves['mezo'][i]:.4f} |")
    mesp_final = np.mean(curves["mesp"][-20:])
    mebp_final = np.mean(curves["mebp"][-20:])
    mezo_final = np.mean(curves["mezo"][-20:])
    match = bool(np.allclose(curves["mesp"], curves["mebp"], rtol=1e-4))
    emit("fig2/mesp_equals_mebp", match, f"{time.monotonic()-t0:.0f}s")
    emit("fig2/final_loss_mesp", f"{mesp_final:.4f}")
    emit("fig2/final_loss_mezo", f"{mezo_final:.4f}",
         f"gap={(mezo_final-mesp_final)/mesp_final:.1%}")
    report(f"\nMeSP ≡ MeBP trajectories: **{match}**; final losses "
           f"MeSP/MeBP {mesp_final:.3f}/{mebp_final:.3f} vs MeZO "
           f"{mezo_final:.3f} ({(mezo_final-mesp_final)/mesp_final:+.1%}).")


# ---------------------------------------------------------------- kernels
def kernels_bench(steps: int = 3):
    """Structured vs pallas per-step timing (bf16- and int8-W0) ->
    BENCH_kernels.json (see benchmarks/kernels.py; interpret-mode numbers
    off-TPU)."""
    from benchmarks import kernels as K
    result = K.run_and_write(steps)
    step = result["train_step"]
    report("## Kernels — structured vs pallas per step "
           f"(backend={result['backend']}, interpret={result['interpret']})")
    report("| path | step ms |")
    report("|---|---|")
    for mode in ("structured", "pallas", "structured_int8", "pallas_int8"):
        emit(f"kernels/{mode}/step_ms", f"{step[mode]['step_ms']:.2f}")
        report(f"| {mode} | {step[mode]['step_ms']:.2f} |")
    emit("kernels/pallas_over_structured",
         f"{step['pallas_over_structured']:.3f}")
    emit("kernels/int8_over_bf16_pallas",
         f"{step['int8_over_bf16_pallas']:.3f}")

    sg = result["per_op"].get("attention_sparse_grid")
    if sg:
        report("\n### Sparse-grid flash attention (causal, "
               f"Nq={sg['shape']['Nq']}, bq=bk={sg['bq']})")
        report("| live tiles | dense tiles | grid fraction | interior | "
               "boundary | sparse fwd+bwd ms | dense fwd+bwd ms | "
               "eff TFLOP/s |")
        report("|---|---|---|---|---|---|---|---|")
        report(f"| {sg['live_tiles']} | {sg['dense_tiles']} | "
               f"{sg['grid_fraction']:.3f} | {sg['interior_tiles']} | "
               f"{sg['boundary_tiles']} | {sg['sparse_fwdbwd_ms']:.2f} | "
               f"{sg['dense_fwdbwd_ms']:.2f} | "
               f"{sg['effective_tflops']:.4f} |")
        emit("kernels/flash/grid_fraction", f"{sg['grid_fraction']:.3f}",
             f"live={sg['live_tiles']}/{sg['dense_tiles']}")
        emit("kernels/flash/dense_over_sparse",
             f"{sg['dense_over_sparse']:.3f}")
        emit("kernels/flash/rope_fused_fwd_ms",
             f"{sg['rope_fused_fwd_ms']:.2f}",
             f"prerotated={sg['rope_prerotated_fwd_ms']:.2f}")


# ------------------------------------------------------------------ quant
def table_quant():
    """Quantized base weights (paper §4.5): int8 / packed int4 / nf4 W0.

    The format sweep is generated from ``core.quant.METHODS`` (a newly
    registered quantize method becomes a column with zero edits here). Sim
    columns use the HBM-resident weight accounting
    (``memsim.resident_weight_mb``) for the paper models; the XLA column
    AOT-compiles the reduced 0.5B-family config per ``quantize`` method and
    reports argument (weight+input) bytes — the quantity the packed formats
    shrink. Activation terms are MeSP's and unchanged by W0 format.
    """
    from benchmarks.memory import measure
    from benchmarks.memsim import resident_weight_mb, simulate
    from repro.configs import get_config
    from repro.core import quant
    fmts = [quant.weights_format(m) for m in quant.METHODS]  # bf16 first
    report("## Quantized base weights — MeSP + int8/int4/nf4 W0 "
           "(dequant-in-VMEM / nibble-unpack kernels) vs bf16 W0, seq 256")
    report("| model | " + " | ".join(f"W0 {f} MB" for f in fmts)
           + " | " + " | ".join(f"{f}/bf16" for f in fmts[1:])
           + " | total bf16 MB | total nf4 MB |")
    # columns: model + |fmts| W0 + |fmts|-1 ratios + 2 totals
    report("|---" * (2 * len(fmts) + 2) + "|")
    for arch in PAPER_MODELS:
        cfg = get_config(arch)
        w = {f: resident_weight_mb(cfg, f) for f in fmts}
        tb = simulate(arch, "mesp", 256, weights_fmt="bf16").total_mb
        tq = simulate(arch, "mesp", 256, weights_fmt=fmts[-1]).total_mb
        for f in fmts[1:]:
            emit(f"quant/{arch}/{f}_weights_mb", f"{w[f]:.1f}",
                 f"bf16={w['bf16']:.1f} ratio={w[f] / w['bf16']:.3f}")
        report("| " + arch + " | "
               + " | ".join(f"{w[f]:.0f}" for f in fmts) + " | "
               + " | ".join(f"{w[f] / w['bf16']:.2f}" for f in fmts[1:])
               + f" | {tb:.0f} | {tq:.0f} |")
    xb = measure("qwen2.5-0.5b", "mesp", seq=256)
    for m in quant.METHODS[1:]:
        xq = measure("qwen2.5-0.5b", "mesp", seq=256, quantize=m)
        emit(f"quant/qwen2.5-0.5b/xla_arg_mb_{m}", f"{xq['arg_mb']:.1f}",
             f"bf16={xb['arg_mb']:.1f}")
        report(f"\nXLA AOT cross-check (qwen2.5-0.5b, mesp): argument bytes "
               f"{xb['arg_mb']:.0f} MB (bf16 W0) → {xq['arg_mb']:.0f} MB "
               f"({m} W0), {1 - xq['arg_mb'] / xb['arg_mb']:.0%} lower.")


# ---------------------------------------------------------------- serving
def table_serving():
    """Multi-tenant serving: continuous-batching tokens/s, grouped-kernel
    schedule, and the serve-side residency split -> BENCH_serving.json
    (see benchmarks/serving.py; wall-clock is annotation-only off-TPU)."""
    from benchmarks import serving as S
    result = S.run_and_write()
    cont, gk, sim = (result["continuous"], result["grouped_kernel"],
                     result["memsim"])
    s = result["setting"]
    report("## Serving — multi-tenant continuous batching "
           f"(backend={result['backend']}, interpret={result['interpret']})")
    report(f"{s['adapters']} tenants / {s['capacity']} resident slots / "
           f"{s['slots']} decode rows (tile {s['tile']}), "
           f"{s['requests']} requests × ({s['prompt_len']} prompt + "
           f"{s['max_new']} new) tokens.")
    report("| trace | tok/s | steps | evictions | store hits/misses | "
           "peak pages |")
    report("|---|---|---|---|---|---|")
    for key in ("multi", "single"):
        c = cont[key]
        emit(f"serving/{key}/tokens_per_s", f"{c['tokens_per_s']:.1f}",
             f"adapters={c['adapters']} evict={c['store']['evictions']}")
        report(f"| {key} ({c['adapters']} adapter"
               f"{'s' if c['adapters'] > 1 else ''}) | "
               f"{c['tokens_per_s']:.0f} | {c['counters']['steps']} | "
               f"{c['store']['evictions']} | {c['store']['hits']}/"
               f"{c['store']['misses']} | {c['pages']['peak_pages']} |")
    sched = gk["schedule"]
    emit("serving/grouped/grid_fraction", f"{sched['grid_fraction']:.3f}",
         f"live={sched['live_tiles']}/{sched['dense_tiles']}")
    emit("serving/grouped/loop_over_grouped",
         f"{gk['loop_over_grouped']:.3f}", f"err={gk['max_abs_err']:.1e}")
    emit("serving/memsim/total_mb", f"{sim['total_mb']:.1f}",
         f"adapters={sim['adapters_mb']:.2f} kv={sim['kv_mb']:.2f}")
    report(f"\nGrouped kernel vs per-adapter loop: {gk['grouped_ms']:.2f} / "
           f"{gk['loop_ms']:.2f} ms (ratio {gk['loop_over_grouped']:.2f}), "
           f"max |err| {gk['max_abs_err']:.1e}; schedule launches "
           f"{sched['live_tiles']}/{sched['dense_tiles']} tiles "
           f"({sched['grid_fraction']:.0%} of the dense grid, "
           f"{sched['empty_groups']} idle tenants skipped). Residency: "
           f"{sim['total_mb']:.1f} MB total ({sim['adapters_mb']:.2f} "
           f"adapters + {sim['kv_mb']:.2f} KV pages).")


TABLES = {"t1": table1, "t2": table2, "t3": table3, "t4": table4,
          "t5": table5, "fig2": figure2, "kernels": kernels_bench,
          "quant": table_quant, "serving": table_serving}


def _merge_report(path, sections):
    """Update per-table ``<!-- section:NAME -->`` chunks in the report,
    keeping sections from earlier runs that were not re-run (so
    ``--only kernels quant`` doesn't wipe t1-t5)."""
    import re
    existing = {}
    if os.path.exists(path):
        txt = open(path).read()
        # pre-marker-era content (or hand-written preamble): keep verbatim
        head = re.split(r"<!-- section:", txt, maxsplit=1)[0].strip("\n")
        if head:
            existing["_legacy"] = head
        for m in re.finditer(r"<!-- section:(\w+) -->\n(.*?)"
                             r"(?=<!-- section:|\Z)", txt, re.S):
            existing[m.group(1)] = m.group(2).strip("\n")
    existing.update(sections)
    order = (["_legacy"] if "_legacy" in existing else []) + \
        [k for k in TABLES if k in existing] + \
        [k for k in existing if k not in TABLES and k != "_legacy"]
    with open(path, "w") as f:
        for k in order:
            f.write(f"<!-- section:{k} -->\n{existing[k]}\n\n")


def main(argv=None):
    global ENGINES
    ENGINES = _engines()  # re-read: pick up engines registered post-import
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=list(TABLES), default=None)
    args = ap.parse_args(argv)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    print("name,value,derived")
    sections = {}
    for name, fn in TABLES.items():
        if args.only and name not in args.only:
            continue
        t0 = time.monotonic()
        mark = len(_report_lines)
        fn()
        sections[name] = "\n".join(_report_lines[mark:]).strip("\n")
        emit(f"{name}/elapsed_s", f"{time.monotonic()-t0:.1f}")
    _merge_report(os.path.join(RESULTS_DIR, "paper_tables.md"), sections)
    print(f"# report: {os.path.join(RESULTS_DIR, 'paper_tables.md')}")


if __name__ == "__main__":
    main()
