"""The generic SPSA estimator over any sampler (paper §3.2, generalized).

Two forward passes per probe at ``θ ± ε z`` give the projected gradient
``(L₊ − L₋)/2ε``, which scales the regenerated ``z`` as the estimate; with
``queries=k`` the estimate is the mean over k independent probes (variance
↓ 1/k, pinned monotone in tests/test_zo.py). Perturbations are regenerated
from the PRNG key at every use — nothing the size of the parameters is ever
stored (see ``repro.zo.samplers``).

``spsa_grad_from_loss`` is deliberately loss-agnostic (used by the toy
quadratic estimator-contract tests); ``spsa_grad`` binds it to the model
stack's LoRA split and is what the ``mezo*`` engine registrations — and the
``core.mezo`` compatibility shim — call. With the dense sampler and one
query it reproduces the original ``core.mezo.spsa_grad`` bit-for-bit (same
leaf order, same per-leaf key split, same op sequence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.policy import PLAIN, ExecutionPolicy
from repro.configs.base import ArchConfig
from repro.zo.samplers import DenseSampler, PerturbationSampler


def perturb(train, z, eps_signed):
    """θ + ε·z leafwise (ε may be negative). Pure/out-of-place: the caller
    keeps ``train``, so no inverse pass over mutated parameters is needed."""
    return jax.tree_util.tree_map(lambda p, zi: p + eps_signed * zi, train, z)


def spsa_grad_from_loss(loss_fn, train, key, *,
                        sampler: PerturbationSampler,
                        eps: float = 1e-3, queries: int = 1):
    """(mean loss, SPSA gradient estimate over ``train``) for any scalar
    ``loss_fn(train)``. ``queries`` probes are averaged."""
    if queries < 1:
        raise ValueError(f"queries must be >= 1, got {queries}")
    keys = [key] if queries == 1 else list(jax.random.split(key, queries))

    loss_acc, grad_acc = None, None
    for k in keys:
        # z is regenerated from the key at each of its three uses (+ε, −ε,
        # gradient construction) — bit-identical by the seed-replay contract
        # — so no z-sized buffer is held across the forward passes. Under
        # jit XLA dedupes the regeneration; eagerly this is the same
        # transient-only footprint the original MeZO loop had.
        l_plus = loss_fn(perturb(train, sampler.sample(k, train), +eps))
        l_minus = loss_fn(perturb(train, sampler.sample(k, train), -eps))
        proj = (l_plus - l_minus) / (2.0 * eps)
        g = jax.tree_util.tree_map(
            lambda p, zi: proj.astype(p.dtype) * zi, train,
            sampler.sample(k, train))
        loss = 0.5 * (l_plus + l_minus)
        if grad_acc is None:
            loss_acc, grad_acc = loss, g
        else:
            loss_acc = loss_acc + loss
            grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, g)
    if queries > 1:
        inv = 1.0 / queries
        loss_acc = loss_acc * inv
        grad_acc = jax.tree_util.tree_map(lambda g: g * inv, grad_acc)
    return loss_acc, grad_acc


def spsa_grad(params, cfg: ArchConfig, batch: dict, key, *,
              sampler: PerturbationSampler | None = None,
              eps: float = 1e-3, queries: int = 1,
              policy: ExecutionPolicy = PLAIN):
    """ZO gradient estimate over the LoRA params of the full model.

    ``policy`` selects the *forward* execution regime for the two probe
    passes (no backward ever runs); the plain backend is the MeZO setting.
    """
    from repro.models import model as model_lib

    sampler = sampler if sampler is not None else DenseSampler()
    train, frozen = model_lib.split_params(params)

    def loss(t):
        return model_lib.loss_fn(model_lib.merge_params(t, frozen), cfg,
                                 batch, policy=policy)

    return spsa_grad_from_loss(loss, train, key, sampler=sampler, eps=eps,
                               queries=queries)


def train_step(params, cfg: ArchConfig, batch: dict, key, lr: float,
               eps: float = 1e-3, *,
               sampler: PerturbationSampler | None = None, queries: int = 1):
    """One plain-SGD ZO step (the ``core.mezo.train_step`` contract)."""
    from repro.models import model as model_lib

    loss, grads = spsa_grad(params, cfg, batch, key, sampler=sampler,
                            eps=eps, queries=queries)
    train, frozen = model_lib.split_params(params)
    new_train = jax.tree_util.tree_map(lambda p, g: p - lr * g, train, grads)
    return model_lib.merge_params(new_train, frozen), loss
