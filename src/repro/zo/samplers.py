"""Perturbation samplers: the pluggable half of the ZO estimator.

A *sampler* decides the distribution of the SPSA probe direction ``z`` over
the trainable (LoRA) pytree. Every sampler is **seed-replay based**: ``z`` is
a pure function of ``(key, train)`` and is regenerated wherever it is needed
(perturb +ε, perturb −ε, gradient construction) instead of being stored —
the property that gives MeZO-style methods their inference-level memory
footprint. ``sample(key, train)`` twice with the same key is bit-identical
(pinned by tests/test_zo.py).

Built-ins (the design space from the related work):

* ``dense``     — z ~ N(0, I) over every LoRA coordinate (vanilla MeZO SPSA,
  paper §3.2). ``E[zzᵀ] = I``.
* ``sparse``    — dense z masked to the top-ρ fraction of coordinates by
  frozen-magnitude ``|w|`` per leaf (Sparse MeZO, arXiv:2402.15751). The
  mask is recomputed from the parameters, never stored. ``E[zzᵀ] = diag(m)``
  — the estimate lives in a subspace ~1/ρ smaller, which is exactly where
  its cosine-similarity gain comes from.
* ``lowrank``   — structured rank-1 noise ``z = s·u vᵀ`` over the trailing
  two axes of each LoRA factor (low-rank-structured ZO, arXiv:2410.07698):
  ``(m+n)`` random degrees of freedom instead of ``m·n``, with a per-leaf
  scale ``s`` from the *paired* factor's RMS — the LoRA chain rule's free
  gradient-magnitude signal (``∂L/∂A ∝ |B|``, ``∂L/∂B ∝ |A|``).
* ``blockwise`` — one transformer block perturbed per probe: stacked
  ``[L, ...]`` leaves are masked to a single shared layer index drawn from
  the key, rescaled by √L so ``E[zzᵀ] = I`` still holds.

``register_sampler`` adds a new sampler; ``repro.zo.engines`` turns each
registered sampler into a ``mezo*`` engine registration (docs/zo.md walks
through adding your own).
"""
from __future__ import annotations

from typing import Callable, Dict, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class PerturbationSampler(Protocol):
    """Deterministic probe-direction generator over the trainable pytree."""

    #: registry name (also the engine-name suffix, see repro.zo.engines)
    name: str

    def sample(self, key, train):
        """z with the structure/shapes/dtypes of ``train``, a pure function
        of ``(key, train)`` — bit-identical on replay with the same key."""
        ...


def _leaf_keys(key, leaves):
    return jax.random.split(key, len(leaves))


class DenseSampler:
    """Vanilla MeZO/SPSA direction: z ~ N(0, I) per LoRA coordinate."""

    name = "dense"

    def sample(self, key, train):
        leaves, treedef = jax.tree_util.tree_flatten(train)
        keys = _leaf_keys(key, leaves)
        zs = [jax.random.normal(k, p.shape, p.dtype)
              for p, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, zs)


class SparseSampler:
    """Sparse MeZO direction: dense z masked to the top-ρ |w| coordinates.

    The mask is a pure function of the current parameter magnitudes
    (per-leaf ``|w| ≥ quantile(|w|, 1−ρ)``) — recomputed at every probe,
    never stored, so the memory-free property is preserved. A leaf whose
    magnitudes are all equal (e.g. LoRA B at init, identically zero)
    degenerates to a dense perturbation of that leaf.
    """

    name = "sparse"

    def __init__(self, rho: float = 0.10):
        if not 0.0 < rho <= 1.0:
            raise ValueError(f"rho must be in (0, 1], got {rho}")
        self.rho = rho

    def _mask(self, p):
        # boolean (1 byte/param — the footprint benchmarks/memsim charges
        # for the mezo_sparse model); the f32 |w| copy for the quantile is
        # per-leaf transient probe working set
        mag = jnp.abs(p).astype(jnp.float32)
        thresh = jnp.quantile(mag.reshape(-1), 1.0 - self.rho)
        return mag >= thresh

    def sample(self, key, train):
        leaves, treedef = jax.tree_util.tree_flatten(train)
        keys = _leaf_keys(key, leaves)
        zs = [jnp.where(self._mask(p),
                        jax.random.normal(k, p.shape, p.dtype),
                        jnp.zeros((), p.dtype))
              for p, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, zs)


def _paired_factor_scales(train):
    """Per-leaf RMS of the *paired* LoRA factor (B for an ``a`` leaf, A for
    a ``b`` leaf; 1.0 when no pair exists).

    This is the chain-rule magnitude signal the LoRA parametrization gives
    away for free: ``∂L/∂A = xᵀδBᵀ`` scales with ``|B|`` and ``∂L/∂B = hᵀδ``
    with ``|h| ∝ |A|`` — so the paired factor's magnitude predicts each
    leaf's gradient scale *from parameters alone* (no gradient peeked,
    nothing stored). Early in adaptation ``|B| ≪ |A|``, which concentrates
    the probe where the gradient mass actually is.
    """
    def entry(k):
        # DictKey has .key, SequenceKey (list levels, e.g. hybrid "tail")
        # has .idx — both must distinguish siblings or per-layer pairs merge
        return getattr(k, "key", getattr(k, "idx", None))

    leaves, _ = jax.tree_util.tree_flatten_with_path(train)
    by_parent: dict = {}
    for path, p in leaves:
        parent = tuple(entry(k) for k in path[:-1])
        by_parent.setdefault(parent, {})[entry(path[-1])] = p
    scales = []
    for path, p in leaves:
        parent = tuple(entry(k) for k in path[:-1])
        pair = by_parent[parent].get({"a": "b", "b": "a"}.get(
            entry(path[-1])))
        scales.append(jnp.sqrt(jnp.mean(pair.astype(jnp.float32) ** 2))
                      if pair is not None else jnp.float32(1.0))
    return scales


class LowRankSampler:
    """Structured rank-1 direction z = s · u vᵀ over each leaf's trailing
    axes (low-rank-structured ZO, arXiv:2410.07698 flavour).

    For a stacked LoRA factor ``[L, m, n]`` this draws ``u ~ N(0,I) [L,m,1]``
    and ``v ~ N(0,I) [L,1,n]`` — ``L(m+n)`` random degrees of freedom instead
    of ``Lmn``, concentrating the probe on the low-rank structure the LoRA
    parametrization already has. ``s`` is the paired factor's RMS
    (:func:`_paired_factor_scales`), a parameter-only preconditioner that
    weights each leaf's probe variance by its predicted gradient scale;
    ``cross_scale=False`` turns it off (s ≡ 1). Leaves with fewer than two
    axes fall back to (scaled) dense noise.
    """

    name = "lowrank"

    def __init__(self, cross_scale: bool = True):
        self.cross_scale = cross_scale

    def sample(self, key, train):
        leaves, treedef = jax.tree_util.tree_flatten(train)
        keys = _leaf_keys(key, leaves)
        scales = (_paired_factor_scales(train) if self.cross_scale
                  else [jnp.float32(1.0)] * len(leaves))

        def one(p, k, s):
            s = s.astype(p.dtype)
            if p.ndim < 2:
                return s * jax.random.normal(k, p.shape, p.dtype)
            ku, kv = jax.random.split(k)
            m, n = p.shape[-2], p.shape[-1]
            u = jax.random.normal(ku, p.shape[:-2] + (m, 1), p.dtype)
            v = jax.random.normal(kv, p.shape[:-2] + (1, n), p.dtype)
            return s * u * v  # broadcast outer product over trailing axes

        return jax.tree_util.tree_unflatten(
            treedef, [one(p, k, s) for p, k, s in zip(leaves, keys, scales)])


class BlockwiseSampler:
    """One transformer block per probe (coordinate-blockwise SPSA).

    One uniform draw from the key selects a layer index; every stacked leaf
    ``[L, ...]`` is masked to that index (modulo its own leading dim) and
    rescaled by √L, so ``E[zzᵀ] = I`` is preserved while each probe touches
    a single block's parameters. Unstacked (< 3-dim) leaves are perturbed
    densely.
    """

    name = "blockwise"

    def sample(self, key, train):
        k_layer, k_noise = jax.random.split(key)
        u = jax.random.uniform(k_layer)  # shared draw → coherent layer pick
        leaves, treedef = jax.tree_util.tree_flatten(train)
        keys = _leaf_keys(k_noise, leaves)

        def one(p, k):
            z = jax.random.normal(k, p.shape, p.dtype)
            if p.ndim < 3:
                return z
            n = p.shape[0]
            idx = jnp.minimum((u * n).astype(jnp.int32), n - 1)
            mask = jax.nn.one_hot(idx, n, dtype=p.dtype)
            mask = mask.reshape((n,) + (1,) * (p.ndim - 1))
            return z * mask * jnp.asarray(n, p.dtype) ** 0.5

        return jax.tree_util.tree_unflatten(
            treedef, [one(p, k) for p, k in zip(leaves, keys)])


# ---------------------------------------------------------------- registry

#: name -> zero/keyword-arg factory returning a PerturbationSampler
SAMPLERS: Dict[str, Callable[..., PerturbationSampler]] = {}


def register_sampler(factory: Callable[..., PerturbationSampler],
                     name: str | None = None):
    """Register a sampler factory (class or callable). Returns the factory so
    it can be used as a decorator: ``@register_sampler``."""
    key = name or factory.name
    if key in SAMPLERS:
        raise ValueError(f"sampler {key!r} is already registered")
    SAMPLERS[key] = factory
    return factory


def get_sampler(name: str, **kw) -> PerturbationSampler:
    try:
        factory = SAMPLERS[name]
    except KeyError:
        raise KeyError(f"unknown sampler {name!r}; registered: "
                       f"{sorted(SAMPLERS)}") from None
    return factory(**kw)


def sampler_names():
    return tuple(SAMPLERS)


for _cls in (DenseSampler, SparseSampler, LowRankSampler, BlockwiseSampler):
    register_sampler(_cls)
