"""repro.zo: pluggable zeroth-order estimators + gradient-quality probes.

Two halves (see docs/zo.md):

* **estimators** — :class:`~repro.zo.samplers.PerturbationSampler` (seed-
  replay probe directions, regenerated from a PRNG key and never stored)
  with dense / sparse / low-rank / blockwise built-ins, and the generic
  SPSA estimator (:func:`~repro.zo.estimator.spsa_grad`, multi-query
  capable) they plug into. ``repro.zo.engines`` registers every variant as
  a ``mezo*`` engine via ``@register_engine`` — CLI / benchmark-sweep /
  memsim / README-matrix membership follows automatically.
* **diagnostics** — :func:`~repro.zo.gradquality.probe` /
  :func:`~repro.zo.gradquality.probe_over_steps` score any registered
  engine's gradient estimate against the exact MeSP reference (the paper's
  §5.6 cosine ≈ 0.001 finding), driving ``benchmarks/gradient_quality.py``.

``core.mezo`` is a thin compatibility shim over this package.
"""
from repro.zo.estimator import (perturb, spsa_grad, spsa_grad_from_loss,
                                train_step)
from repro.zo.samplers import (SAMPLERS, BlockwiseSampler, DenseSampler,
                               LowRankSampler, PerturbationSampler,
                               SparseSampler, get_sampler, register_sampler,
                               sampler_names)

__all__ = [
    "BlockwiseSampler", "DenseSampler", "LowRankSampler",
    "PerturbationSampler", "SAMPLERS", "SparseSampler", "get_sampler",
    "perturb", "register_sampler", "sampler_names", "spsa_grad",
    "spsa_grad_from_loss", "train_step",
]
