"""Gradient-quality probe: any registered engine vs the exact MeSP gradient.

The paper's second headline result (§5.6, Table 3) is diagnostic: MeZO's
SPSA estimates have near-zero cosine similarity (≈0.001) with true
gradients. This module makes that measurement first-class for *any*
registered engine: :func:`probe` scores one estimate against the reference
engine's exact gradient on one batch (global + per-layer metrics, via
``core.gradcheck``); :func:`probe_over_steps` tracks the metrics over a real
training trajectory (params advanced with the exact reference gradients
between probes) and aggregates — the machinery behind
``benchmarks/gradient_quality.py`` and its committed
``BENCH_gradient_quality.json``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.api.policy import PLAIN, STRUCTURED
from repro.api.registry import get_engine, list_engines
from repro.core import gradcheck


def zo_engine_names() -> tuple:
    """Registered zeroth-order engines: ``backend=None`` (no backward regime
    to select — probes are plain forwards) plus a ``value_and_grad`` hook."""
    return tuple(e.name for e in list_engines()
                 if e.backend is None and e.value_and_grad is not None)


def _stacked_layers(grads) -> int:
    """Leading (layer) dim of the stacked ``blocks`` grads, or 0 when the
    tree has no such entry. cfg.n_layers is *not* used: layouts like MoE
    ``first_layer_dense`` keep one block unstacked (``block0``), and JAX
    clamps out-of-bounds integer indexing silently."""
    if not (isinstance(grads, dict) and "blocks" in grads):
        return 0
    leaves = jax.tree_util.tree_leaves(grads["blocks"])
    return int(leaves[0].shape[0]) if leaves else 0


def probe(engine: str, params, cfg, batch, key, *,
          reference: str = "mesp") -> dict:
    """Score one gradient estimate against the reference engine's gradient.

    Returns ``{"global": {cosine_sim, sign_agree, rel_error},
    "per_layer": [...] | None}`` (per-layer only for param trees with a
    stacked ``blocks`` entry; rows cover the stacked blocks, so for layouts
    with an unstacked leading block — MoE ``first_layer_dense`` — row i is
    transformer layer i+1).
    """
    ref = get_engine(reference)
    eng = get_engine(engine)
    _, g_true = ref.value_and_grad(params, cfg, batch, policy=STRUCTURED)
    _, g_est = eng.value_and_grad(params, cfg, batch, policy=PLAIN, key=key)
    out = {"global": {k: float(v) for k, v in
                      gradcheck.gradient_metrics(g_est, g_true).items()},
           "per_layer": None}
    n = _stacked_layers(g_true)
    if n:
        out["per_layer"] = gradcheck.per_layer_metrics(
            g_est["blocks"], g_true["blocks"], n)
    return out


def probe_over_steps(engines: Sequence[str], cfg, *, steps: int = 16,
                     warmup: int = 10, lr: float = 5e-2, seed: int = 0,
                     seq: int = 48, batch: int = 2, probes: int = 1,
                     reference: str = "mesp",
                     per_layer: bool = True) -> Dict[str, dict]:
    """Aggregate gradient-quality metrics over a training trajectory.

    The model is warmed up ``warmup`` steps (so LoRA B ≠ 0 — at init
    dL/dA ≡ 0 exactly, degenerating the statistics and the magnitude-
    structured samplers' masks/scales), then for each of
    ``steps`` further steps every engine's estimate is scored against the
    reference gradient on the *same* batch, after which the params advance
    one exact-gradient step. A single SPSA cosine is noisy by nature (std ~
    its mean); ``probes`` independent estimates are scored per (step,
    engine) and the mean over all steps × probes is the stable quantity
    reported (``cosine_sem`` gives its standard error).
    """
    from repro.core import mesp
    from repro.data import make_batch_iterator
    from repro.models import model as model_lib

    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    it = make_batch_iterator(cfg.vocab, seq, batch, seed=seed)
    train_step = jax.jit(lambda p, b: mesp.train_step(p, cfg, b, lr))
    for _ in range(warmup):
        params, _ = train_step(params, next(it))

    ref = get_engine(reference)
    ref_vag = jax.jit(lambda p, b: ref.value_and_grad(p, cfg, b,
                                                      policy=STRUCTURED))
    # advance with the reference grads already computed for scoring (same
    # SGD rule as mesp.train_step — avoids a second exact backward per step)
    apply_sgd = jax.jit(lambda p, g: jax.tree_util.tree_map(
        lambda pi, gi: pi if gi is None else (pi - lr * gi.astype(pi.dtype)),
        p, g, is_leaf=lambda x: x is None))
    est_vags = {
        name: jax.jit(lambda p, b, k, _vag=get_engine(name).value_and_grad:
                      _vag(p, cfg, b, policy=PLAIN, key=k))
        for name in engines}

    records: Dict[str, List[dict]] = {n: [] for n in engines}
    layer_cos: Dict[str, List[np.ndarray]] = {n: [] for n in engines}
    base_key = jax.random.PRNGKey(seed + 1)
    for t in range(steps):
        b = next(it)
        _, g_true = ref_vag(params, b)
        step_key = jax.random.fold_in(base_key, t)
        for i, name in enumerate(engines):
            eng_key = jax.random.fold_in(step_key, i)
            for pr in range(probes):
                key = jax.random.fold_in(eng_key, pr)
                _, g_est = est_vags[name](params, b, key)
                m = gradcheck.gradient_metrics(g_est, g_true)
                records[name].append({k: float(v) for k, v in m.items()})
                n_stacked = _stacked_layers(g_true) if per_layer else 0
                if n_stacked:
                    rows = gradcheck.per_layer_metrics(
                        g_est["blocks"], g_true["blocks"], n_stacked)
                    layer_cos[name].append(
                        np.array([r["cosine_sim"] for r in rows]))
        params = apply_sgd(params, g_true)

    out: Dict[str, dict] = {}
    for name in engines:
        cos = np.array([r["cosine_sim"] for r in records[name]])
        out[name] = {
            "steps": steps,
            "probes": probes,
            "cosine_mean": float(cos.mean()),
            "cosine_std": float(cos.std()),
            "cosine_sem": float(cos.std() / np.sqrt(len(cos))),
            "cosine_abs_mean": float(np.abs(cos).mean()),
            "sign_agree_mean": float(np.mean(
                [r["sign_agree"] for r in records[name]])),
            "rel_error_mean": float(np.mean(
                [r["rel_error"] for r in records[name]])),
        }
        if layer_cos[name]:
            out[name]["per_layer_cosine_mean"] = [
                float(v) for v in np.stack(layer_cos[name]).mean(axis=0)]
    return out
