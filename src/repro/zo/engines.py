"""ZO engine registrations: every sampler × query combination as an engine.

Importing this module (done by ``repro.api.engines``, i.e. lazily by the
registry) registers one engine per entry in ``_VARIANTS``. Each registration
goes through the ordinary ``@register_engine`` path, so the CLI ``--engine``
choices, the benchmark sweep, ``benchmarks/memsim`` resident-memory tables
and the README engine-matrix check all pick the variants up with **zero
edits** to ``launch/``, ``benchmarks/run.py`` or ``models/*`` (the PR 3
property, pinned by tests/test_api.py).

All ZO engines share the estimator in ``repro.zo.estimator``; the variants
differ only in the :class:`~repro.zo.samplers.PerturbationSampler` and the
number of averaged probes. ``backend=None`` (two plain forwards, no
backward) is what marks an engine as zeroth-order throughout the repo —
``benchmarks/gradient_quality.py`` selects its sweep that way.

To add a new variant: register a sampler (``repro.zo.samplers``), add a
``_Variant`` row here, document it in the README matrix (CI enforces the
last step). docs/zo.md has the walkthrough.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

from repro.api.registry import register_engine
from repro.zo import estimator
from repro.zo.samplers import get_sampler


@dataclasses.dataclass(frozen=True)
class _Variant:
    engine: str            # registered engine name
    sampler: str           # repro.zo.samplers registry name
    sampler_kw: tuple      # sorted (key, value) pairs for the factory
    queries: int           # probes averaged per step
    memsim: str            # analytical retention model (benchmarks/memsim)
    paper: str
    description: str


_VARIANTS: Tuple[_Variant, ...] = (
    _Variant("mezo", "dense", (), 1, "mezo", "§3.2",
             "MeZO baseline: SPSA zeroth-order estimate from two plain "
             "forward passes"),
    _Variant("mezo_sparse", "sparse", (("rho", 0.10),), 1, "mezo_sparse",
             "§5.6 + 2402.15751",
             "Sparse-MeZO-style SPSA: probe masked to the top-10% |w| "
             "coordinates per leaf (mask recomputed, never stored)"),
    _Variant("mezo_lowrank", "lowrank", (), 1, "mezo",
             "§5.6 + 2410.07698",
             "low-rank-structured SPSA: rank-1 u vT probe per LoRA factor, "
             "scaled by the paired factor's RMS (chain-rule magnitude "
             "signal)"),
    _Variant("mezo_block", "blockwise", (), 1, "mezo", "§5.6",
             "blockwise SPSA: one transformer block perturbed per probe "
             "(stacked leaves masked to a shared layer index)"),
    _Variant("mezo_avg4", "dense", (), 4, "mezo", "§3.2 + §5.6",
             "MeZO with multi-query averaging: mean of 4 independent dense "
             "SPSA probes per step (variance / 4)"),
)


def _register(v: _Variant):
    sampler = get_sampler(v.sampler, **dict(v.sampler_kw))

    def vag(params, cfg, batch, *, policy, key=None):
        # policy is accepted for hook uniformity; ZO probes always run the
        # plain forward regime (no backward exists to select)
        key = key if key is not None else jax.random.PRNGKey(0)
        return estimator.spsa_grad(params, cfg, batch, key, sampler=sampler,
                                   queries=v.queries)

    @register_engine(v.engine, backend=None, memsim=v.memsim, paper=v.paper,
                     value_and_grad=vag, description=v.description)
    def build(spec, cfg, opt, policy, _v=v, _sampler=sampler):
        # perturbation stream derives from the spec's seed (folded per step)
        base_key = jax.random.PRNGKey(spec.seed)

        def step(params, opt_state, batch):
            key = jax.random.fold_in(base_key, opt_state["step"])
            loss, grads = estimator.spsa_grad(params, cfg, batch, key,
                                              sampler=_sampler,
                                              queries=_v.queries)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        return step

    return build


# The authoritative "which engines are ZO" query is registry-derived
# (repro.zo.gradquality.zo_engine_names) so that engines registered outside
# _VARIANTS — the docs/zo.md walkthrough path — are included too.
for _v in _VARIANTS:
    _register(_v)
