"""DeepSeekMoE-16B [arXiv:2401.06066; hf].

28L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=102400,
2 shared + 64 routed top-6 (fine-grained experts), first layer dense FFN.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_expert=1408, n_shared=2,
        first_layer_dense=True,
    ),
    notes="2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066; hf]",
)
