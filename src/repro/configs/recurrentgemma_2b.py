"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000,
RG-LRU recurrent blocks : local attention 2:1 (pattern R,R,A), window 2048.
Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("R", "R", "A"), lru_width=2560, window=2048),
    subquadratic=True,
    notes="RG-LRU + local attn, 2:1 [arXiv:2402.19427; hf]",
)
