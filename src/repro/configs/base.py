"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`. Configs are
frozen dataclasses so they can be used as static (hashable) jit arguments.

``ArchConfig.reduced()`` returns a tiny same-family config used by CPU smoke
tests; the full config is only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LoRAConfig:
    """Paper setup: rank 8, alpha 16, applied to q,k,v,o,gate,up,down."""

    rank: int = 8
    alpha: float = 16.0
    targets: Tuple[str, ...] = ("q", "k", "v", "o", "gate", "up", "down")

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    first_layer_dense: bool = False  # deepseek-moe: layer 0 is a dense FFN


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style block pattern."""

    pattern: Tuple[str, ...] = ("R", "R", "A")  # repeated; truncated to n_layers
    lru_width: int = 0  # defaults to d_model when 0
    window: int = 2048  # local attention window for 'A' blocks


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder."""

    encoder_layers: int = 4
    encoder_seq: int = 1500  # precomputed mel-frame embeddings (stub frontend)


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "vlm", "audio", "ssm", "hybrid")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # d_model // n_heads unless overridden
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # attention layout: per-layer sliding window sizes; () => all-global.
    # gemma3 uses 5 local : 1 global.
    window_pattern: Tuple[int, ...] = ()  # 0 = global, >0 = local window

    moe: Optional[MoEConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None

    lora: LoRAConfig = field(default_factory=LoRAConfig)

    # frontend stub for [vlm]/[audio]: input_specs() provides precomputed
    # patch/frame embeddings of this many positions prepended to the text.
    frontend_tokens: int = 0

    # True when the arch can run the long_500k shape (sub-quadratic mixing).
    subquadratic: bool = False
    notes: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_size(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_size(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_window(self, i: int) -> int:
        if not self.window_pattern:
            return 0
        return self.window_pattern[i % len(self.window_pattern)]

    def n_params(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.moe is not None:
            ff = 3 * d * self.moe.d_expert * (self.moe.n_experts + self.moe.n_shared)
            ff += d * self.moe.n_experts  # router
        else:
            ff = 3 * d * self.d_ff
        per_layer = attn + ff
        if self.family == "ssm":
            per_layer = 5 * d * d + 3 * d * self.d_ff  # rwkv6 approx
        total = emb + L * per_layer
        if self.encdec is not None:
            total += self.encdec.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE uses top-k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        ff = 3 * d * self.moe.d_expert * (self.moe.top_k + self.moe.n_shared)
        return emb + L * (attn + ff)

    # ---- reduced config for smoke tests -------------------------------------
    def reduced(self) -> "ArchConfig":
        """A tiny same-family config: runs one train/serve step on CPU."""
        changes = dict(
            n_layers=min(self.n_layers, 3 if self.hybrid else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            dtype="float32",
            frontend_tokens=min(self.frontend_tokens, 4),
            lora=LoRAConfig(rank=4, alpha=8.0, targets=self.lora.targets),
        )
        if self.window_pattern:
            # keep the local:global character with a 2-layer (local, global)
            # period so the reduced model stays tiny
            changes["window_pattern"] = (8, 0)
            changes["n_layers"] = 2
        if self.moe is not None:
            changes["moe"] = MoEConfig(
                n_experts=4, top_k=2, d_expert=32,
                n_shared=min(self.moe.n_shared, 1),
                first_layer_dense=self.moe.first_layer_dense,
            )
        if self.hybrid is not None:
            changes["hybrid"] = HybridConfig(
                pattern=self.hybrid.pattern, lru_width=64, window=8
            )
        if self.encdec is not None:
            changes["encdec"] = EncDecConfig(encoder_layers=2, encoder_seq=8)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason string when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic mixing (DESIGN.md §5)"
    return True, ""
