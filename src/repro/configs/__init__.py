"""Config registry: one module per assigned architecture (+ the paper's own

Qwen2.5 family used in the reproduction benchmarks). ``get_config(name)``
returns the full ArchConfig; ``--arch <id>`` in the launchers resolves here.
"""
from __future__ import annotations

from .base import (
    ArchConfig,
    EncDecConfig,
    HybridConfig,
    LoRAConfig,
    MoEConfig,
    ShapeConfig,
    SHAPES,
    shape_applicable,
)

from . import (
    olmoe_1b_7b,
    deepseek_moe_16b,
    granite_8b,
    gemma3_12b,
    qwen2_5_32b,
    minitron_4b,
    internvl2_1b,
    whisper_tiny,
    rwkv6_1_6b,
    recurrentgemma_2b,
    qwen2_5_paper,
)

_MODULES = [
    olmoe_1b_7b, deepseek_moe_16b, granite_8b, gemma3_12b, qwen2_5_32b,
    minitron_4b, internvl2_1b, whisper_tiny, rwkv6_1_6b, recurrentgemma_2b,
]

REGISTRY = {}
for _m in _MODULES:
    REGISTRY[_m.CONFIG.name] = _m.CONFIG
for _c in qwen2_5_paper.CONFIGS:
    REGISTRY[_c.name] = _c

ASSIGNED = tuple(m.CONFIG.name for m in _MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ArchConfig", "LoRAConfig", "MoEConfig", "HybridConfig", "EncDecConfig",
    "ShapeConfig", "SHAPES", "shape_applicable", "REGISTRY", "ASSIGNED",
    "get_config",
]
