"""RWKV6 (Finch) 1.6B [arXiv:2404.05892; unverified].

24L d_model=2048 attention-free (data-dependent decay WKV), d_ff=7168
channel-mix, vocab=65536. head count used only for WKV state blocking
(32 heads of dim 64). Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,        # WKV head blocking
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    head_dim=64,
    subquadratic=True,
    notes="Finch — data-dependent decay [arXiv:2404.05892; unverified]",
)
