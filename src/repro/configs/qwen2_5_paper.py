"""The paper's own model family: Qwen2.5 0.5B / 1.5B / 3B [Qwen Team 2024].

Used by the reproduction benchmarks (Tables 1,2,4,5, Fig. 2). LoRA rank 8
applied to q,k,v,o,gate,up,down per the paper's §5.1.
"""
from .base import ArchConfig

CONFIGS = [
    ArchConfig(
        name="qwen2.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151936,
        head_dim=64,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        notes="paper model (Table 1 row 1)",
    ),
    ArchConfig(
        name="qwen2.5-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        head_dim=128,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        notes="paper model (Table 1 row 2)",
    ),
    ArchConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab=151936,
        head_dim=128,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        notes="paper model (Table 1 row 3)",
    ),
]
