"""Gemma3-12B [hf:google/gemma-3-1b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local(window=1024):global attention pattern, 128k context. head_dim=256.

Sub-quadratic eligible: 5/6 of layers are sliding-window; long_500k decode is
dominated by windowed layers and the 1/6 global layers attend over the sharded
KV cache (decode is O(cache) per token, not O(cache^2)).
"""
from .base import ArchConfig

# pattern entry 0 = global, >0 = sliding window
_PATTERN = (1024, 1024, 1024, 1024, 1024, 0)  # 5 local : 1 global

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    window_pattern=_PATTERN,
    subquadratic=True,
    notes="5:1 local:global, 128k [hf:google/gemma-3-1b-pt; unverified]",
)
