"""Whisper-tiny [arXiv:2212.04356; unverified] — enc-dec transformer backbone.

4L(dec) d_model=384 6H d_ff=1536 vocab=51865; 4 encoder layers over 1500
precomputed mel-frame embeddings (conv frontend STUB per assignment).
Decoder has self-attention (causal, cached at decode) + cross-attention.
"""
from .base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    encdec=EncDecConfig(encoder_layers=4, encoder_seq=1500),
    notes="enc-dec, conv frontend stubbed [arXiv:2212.04356; unverified]",
)
