"""InternVL2-1B [arXiv:2404.16821; hf] — LM backbone (InternLM2-style).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The InternViT
frontend is a STUB per the assignment: input_specs() provides precomputed
patch embeddings (256 tokens) prepended to the text sequence.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    frontend_tokens=256,
    notes="InternViT + InternLM2; vision frontend stubbed [arXiv:2404.16821; hf]",
)
