from repro.data.pipeline import (  # noqa: F401
    DataState, TokenStream, make_batch_iterator, synthetic_corpus,
)
