"""Deterministic, restartable, host-sharded data pipeline.

The paper fine-tunes on WikiText-2 with batch 1. Offline here, so the
pipeline consumes any token source (a synthetic Zipfian LM corpus by default,
or a tokenized ``.npy``/text file), packs it into fixed-length sequences, and
yields next-token-prediction batches.

Determinism & fault tolerance: iteration state is a ``DataState`` (epoch,
cursor, rng) that is saved inside training checkpoints and restored on
restart — a resumed run sees exactly the token stream it would have seen
(tested in tests/test_data.py). Multi-host sharding slices each global batch
by ``(host_index, host_count)`` so every host materializes only its shard.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataState:
    epoch: int = 0
    cursor: int = 0
    seed: int = 0

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


def synthetic_corpus(vocab: int, n_tokens: int, seed: int = 0) -> np.ndarray:
    """Zipfian token stream with local n-gram structure (so loss can drop)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=n_tokens, p=probs).astype(np.int32)
    # inject bigram structure: every even position repeats prev+1 mod vocab
    toks[1::2] = (toks[0::2][: len(toks[1::2])] + 1) % vocab
    return toks


class TokenStream:
    """Packs a flat token array into [batch, seq+1] windows, restartable."""

    def __init__(self, tokens: np.ndarray, seq_len: int, batch: int,
                 state: Optional[DataState] = None):
        self.tokens = tokens
        self.seq_len = seq_len
        self.batch = batch
        self.state = state or DataState()
        self._per_step = batch * (seq_len + 1)
        if len(tokens) < self._per_step:
            reps = -(-self._per_step // len(tokens))
            self.tokens = np.tile(tokens, reps)

    def __iter__(self):
        return self

    def __next__(self):
        n = len(self.tokens)
        if self.state.cursor + self._per_step > n:
            self.state.epoch += 1
            self.state.cursor = 0
            # deterministic per-epoch shuffle of window offsets
            rng = np.random.default_rng(self.state.seed + self.state.epoch)
            self._offset = int(rng.integers(0, self.seq_len))
        start = self.state.cursor + getattr(self, "_offset", 0)
        start = min(start, n - self._per_step)
        chunk = self.tokens[start:start + self._per_step]
        self.state.cursor += self._per_step
        arr = chunk.reshape(self.batch, self.seq_len + 1)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_batch_iterator(vocab: int, seq_len: int, global_batch: int, *,
                        host_index: int = 0, host_count: int = 1,
                        n_tokens: int = 1 << 20, seed: int = 0,
                        state: Optional[DataState] = None,
                        corpus: Optional[np.ndarray] = None) -> TokenStream:
    """Host-sharded iterator: each host gets global_batch / host_count rows."""
    assert global_batch % host_count == 0, \
        f"global_batch {global_batch} must divide over {host_count} hosts"
    local_batch = global_batch // host_count
    toks = corpus if corpus is not None else synthetic_corpus(
        vocab, n_tokens, seed)
    # disjoint host shards of the corpus → no duplicate samples across hosts
    shard = len(toks) // host_count
    local = toks[host_index * shard:(host_index + 1) * shard]
    return TokenStream(local, seq_len, local_batch, state=state)
