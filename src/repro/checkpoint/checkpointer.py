"""Atomic, mesh-agnostic checkpointing with auto-resume.

Design for 1000+ node fault tolerance:

* **Atomicity**: writes go to ``step_XXXXXX.tmp/`` and are renamed to
  ``step_XXXXXX/`` only after a manifest with content checksums is fsynced.
  A crash mid-write can never corrupt the latest valid checkpoint.
* **Mesh-agnostic**: arrays are saved in logical (unsharded) layout with the
  pytree structure; on restore they are re-sharded to whatever mesh/sharding
  the restarting job uses — so a job can come back on a *different* topology
  (elastic restart, DESIGN.md §4).
* **Data-state**: the training-data iterator state and RNG are part of the
  manifest, so a resumed run continues the exact token stream.
* **Retention**: ``keep`` latest checkpoints are retained; older ones are
  garbage-collected after a successful save.

Arrays are stored one ``.npy`` per leaf (keyed by flattened tree path) —
no external deps, streaming-friendly.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), leaf) for p, leaf in flat], treedef


def save_checkpoint(directory: str, step: int, params: Any,
                    opt_state: Any = None, data_state: Optional[dict] = None,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "arrays": {}, "data_state": data_state or {},
                "extra": extra or {}}
    for name, tree in (("params", params), ("opt_state", opt_state)):
        if tree is None:
            continue
        named, _ = _flatten_with_names(tree)
        for key, leaf in named:
            arr = np.asarray(jax.device_get(leaf))
            fn = f"{name}__{key.replace('/', '.')}.npy"
            np.save(os.path.join(tmp, fn), arr)
            digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            manifest["arrays"][fn] = {
                "tree": name, "path": key, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256_16": digest,
            }

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    # retention GC
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def _list_steps(directory: str):
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = _list_steps(directory)
    return max(steps) if steps else None


def _verify(directory: str, fn: str, meta: dict) -> np.ndarray:
    arr = np.load(os.path.join(directory, fn))
    digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
    if digest != meta["sha256_16"]:
        raise IOError(f"checksum mismatch for {fn}: checkpoint corrupt")
    return arr


def load_checkpoint(directory: str, step: int, params_template: Any,
                    opt_template: Any = None, *, shardings=None,
                    verify: bool = True) -> Tuple[Any, Any, dict, dict]:
    """Restore into the templates' tree structure (and shardings, if given)."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_tree = {"params": {}, "opt_state": {}}
    for fn, meta in manifest["arrays"].items():
        arr = _verify(d, fn, meta) if verify else np.load(os.path.join(d, fn))
        by_tree[meta["tree"]][meta["path"]] = arr

    def restore(template, name, shards):
        if template is None:
            return None
        named, treedef = _flatten_with_names(template)
        leaves = []
        shard_leaves = (jax.tree_util.tree_leaves(shards)
                        if shards is not None else [None] * len(named))
        for (key, leaf), sh in zip(named, shard_leaves):
            arr = by_tree[name].get(key)
            if arr is None:
                raise KeyError(f"checkpoint missing leaf {name}/{key}")
            a = np.asarray(arr, dtype=np.asarray(leaf).dtype) \
                if hasattr(leaf, "dtype") else arr
            leaves.append(jax.device_put(a, sh) if sh is not None
                          else jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = restore(params_template, "params",
                     shardings if shardings is not None else None)
    opt = restore(opt_template, "opt_state", None)
    return params, opt, manifest.get("data_state", {}), manifest.get("extra", {})


def quarantine_checkpoint(directory: str, step: int) -> str:
    """Move a corrupt checkpoint dir out of the restore path by renaming it
    ``corrupt_step_XXXXXXXX`` (kept on disk for post-mortems; the
    ``step_``-prefix listing no longer sees it)."""
    src = os.path.join(directory, f"step_{step:08d}")
    dst = os.path.join(directory, f"corrupt_step_{step:08d}")
    if os.path.exists(dst):
        shutil.rmtree(dst)
    os.rename(src, dst)
    return dst


class Checkpointer:
    """Convenience wrapper bundling directory + interval + auto-resume.

    ``restore_latest`` survives corruption: a checkpoint that fails checksum
    verification (or cannot be loaded at all) is quarantined —
    renamed ``corrupt_step_*`` and recorded in ``self.quarantined`` — and
    the next-older checkpoint is tried, so one bad write never loses the
    run. Only when *every* checkpoint fails does it raise ``IOError``.
    """

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self.quarantined: list = []   # (step, reason) in quarantine order

    def maybe_save(self, step: int, params, opt_state=None, data_state=None,
                   extra=None) -> Optional[str]:
        if step % self.interval != 0:
            return None
        return self.save(step, params, opt_state, data_state, extra)

    def save(self, step: int, params, opt_state=None, data_state=None,
             extra=None) -> str:
        """Unconditional (interval-ignoring) save — the resilient loop uses
        this for the forced final checkpoint and post-degradation saves."""
        return save_checkpoint(self.directory, step, params, opt_state,
                               data_state, extra, keep=self.keep)

    def read_manifest(self, step: int) -> dict:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    def restore_latest(self, params_template=None, opt_template=None, *,
                       template_fn=None, **kw):
        """Restore the newest valid checkpoint, falling back over corrupt
        ones (quarantining each). ``template_fn(extra) -> (params_template,
        opt_template)`` lets the caller build templates per candidate from
        its recorded manifest ``extra`` (the Trainer reconstitutes the
        degraded TrainSpec this way); otherwise the given templates apply
        to every candidate."""
        steps = sorted(_list_steps(self.directory), reverse=True) \
            if os.path.isdir(self.directory) else []
        if not steps:
            return None
        for step in steps:
            try:
                if template_fn is not None:
                    manifest = self.read_manifest(step)
                    pt, ot = template_fn(manifest.get("extra", {}))
                else:
                    pt, ot = params_template, opt_template
                params, opt, data_state, extra = load_checkpoint(
                    self.directory, step, pt, ot, **kw)
                return {"step": step, "params": params, "opt_state": opt,
                        "data_state": data_state, "extra": extra}
            except (IOError, OSError, KeyError, ValueError,
                    json.JSONDecodeError) as e:
                quarantine_checkpoint(self.directory, step)
                self.quarantined.append((step, str(e)))
                import logging
                logging.getLogger("repro.ckpt").warning(
                    "checkpoint step %d failed verification (%s); "
                    "quarantined, falling back to next-older", step, e)
        raise IOError(
            f"no restorable checkpoint in {self.directory}: all "
            f"{len(steps)} candidates failed verification and were "
            f"quarantined ({[s for s, _ in self.quarantined]})")
