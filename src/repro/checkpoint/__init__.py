from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer, latest_step, load_checkpoint, quarantine_checkpoint,
    save_checkpoint,
)
