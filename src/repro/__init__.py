"""repro: MeSP (Memory-Efficient Structured Backpropagation) JAX framework."""
__version__ = "1.0.0"
