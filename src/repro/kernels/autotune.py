"""Block-size selection for the Pallas kernels.

Two layers:

1. **Heuristic table** (:func:`choose_blocks`) — shape/dtype-keyed rules that
   pick MXU-friendly block sizes without running anything. This is what the
   dispatch layer (``ops.py``) uses by default; it is deterministic at trace
   time so jit caches stay stable.
2. **Measured autotune** (:func:`autotune`) — optional: time a candidate
   sweep for an op instance and cache the winner, keyed by
   ``(op, dims, dtype, backend)``. The cache is consulted by
   :func:`choose_blocks` before the heuristics, and can be persisted to a
   JSON file (``save_cache``/``load_cache``; ``REPRO_AUTOTUNE_CACHE`` names a
   file to load at import). Benchmarks run it explicitly; training never
   blocks on measurement.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.tiling import ceil_to

# key -> {"bm": ..., ...}
_CACHE: Dict[str, Dict[str, int]] = {}


def _key(op: str, dims: Dict[str, int], dtype) -> str:
    d = "/".join(f"{k}={v}" for k, v in sorted(dims.items()))
    return f"{op}|{d}|{jnp.dtype(dtype).name}|{jax.default_backend()}"


# ---------------------------------------------------------------------------
# heuristics
# ---------------------------------------------------------------------------

# Soft VMEM budget per resident block set (bytes). Real VMEM is ~16 MB/core;
# leave room for double buffering and scratch.
_VMEM_BUDGET = 4 << 20


def _pick(n: int, tiers: Iterable[int]) -> int:
    """Largest tier that n fills completely; 128 floor otherwise."""
    for t in tiers:
        if n >= t:
            return t
    return 128


def _matmul_blocks(M: int, K: int, N: int, dtype,
                   w_itemsize: Optional[int] = None) -> Dict[str, int]:
    """``w_itemsize``: bytes/elem of the weight tile when it differs from
    the activation dtype (int8-W0 kernels pass 1 — the smaller tile admits
    larger K/N blocks for the same VMEM residency)."""
    bm = _pick(M, (256,))
    bn = _pick(N, (512, 256))
    bk = _pick(K, (512, 256))
    # shrink until x/w/acc blocks fit the soft budget
    item = jnp.dtype(dtype).itemsize
    w_item = item if w_itemsize is None else w_itemsize
    while (bm * bk * item + bk * bn * w_item + bm * bn * 4) > _VMEM_BUDGET \
            and max(bm, bn, bk) > 128:
        if bk >= bn and bk > 128:
            bk //= 2
        elif bn > 128:
            bn //= 2
        else:
            bm //= 2
    return {"bm": bm, "bn": bn, "bk": bk}


def _heuristic(op: str, dims: Dict[str, int], dtype) -> Dict[str, int]:
    if op in ("lora_fused", "lora_dx"):
        return _matmul_blocks(dims["M"], dims["K"], dims["N"], dtype)
    if op in ("lora_fused_q", "lora_dx_q"):
        return _matmul_blocks(dims["M"], dims["K"], dims["N"], dtype,
                              w_itemsize=1)
    if op == "lora_dab":
        # grid is rows-only; x[bm,K] and g[bm,N] are both resident
        item = jnp.dtype(dtype).itemsize
        bm = _pick(dims["M"], (512, 256))
        K, N = dims["K"], dims["N"]
        while bm > 128 and bm * (ceil_to(K, 128) + ceil_to(N, 128)) * item \
                > _VMEM_BUDGET:
            bm //= 2
        return {"bm": bm}
    if op == "rmsnorm":
        d = max(dims["d"], 1)
        bm = _pick(dims["M"], (512, 256))
        while bm > 128 and bm * d * 4 > _VMEM_BUDGET:
            bm //= 2
        return {"bm": bm}
    if op == "flash":
        D = dims.get("D", 128)
        bq = _pick(dims["Nq"], (512, 256) if D <= 64 else (256,))
        bk = _pick(dims["Nk"], (512, 256) if D <= 64 else (256,))
        return {"bq": bq, "bk": bk}
    raise ValueError(f"unknown op {op!r}")


def choose_blocks(op: str, dtype=jnp.float32, **dims: int) -> Dict[str, int]:
    """Measured-cache lookup, falling back to the heuristic table."""
    hit = _CACHE.get(_key(op, dims, dtype))
    if hit is not None:
        return dict(hit)
    return _heuristic(op, dims, dtype)


# ---------------------------------------------------------------------------
# measured autotune
# ---------------------------------------------------------------------------


def _time_once(fn: Callable[[], object]) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def autotune(op: str, run: Callable[[Dict[str, int]], object], *,
             candidates: Iterable[Dict[str, int]],
             dtype=jnp.float32, repeats: int = 3,
             **dims: int) -> Dict[str, int]:
    """Measure ``run(blocks)`` for each candidate, cache and return the best.

    ``run`` must execute the kernel with the given block sizes and return a
    JAX value (used for ``block_until_ready``). Candidates that fail to
    compile/execute (e.g. VMEM overflow on real TPUs) are skipped.
    """
    best, best_t = None, float("inf")
    for blocks in candidates:
        try:
            _time_once(lambda: run(blocks))          # compile + warm
            t = min(_time_once(lambda: run(blocks)) for _ in range(repeats))
        except Exception:
            continue
        if t < best_t:
            best, best_t = dict(blocks), t
    if best is None:
        best = _heuristic(op, dims, dtype)
    _CACHE[_key(op, dims, dtype)] = dict(best)
    return best


def load_cache(path: str) -> int:
    """Merge a JSON cache file; returns number of entries loaded."""
    with open(path) as f:
        data = json.load(f)
    _CACHE.update({k: {kk: int(vv) for kk, vv in v.items()}
                   for k, v in data.items()})
    return len(data)


def save_cache(path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(_CACHE, f, indent=1, sort_keys=True)


_env_cache = os.environ.get("REPRO_AUTOTUNE_CACHE")
if _env_cache and os.path.exists(_env_cache):
    try:
        load_cache(_env_cache)
    except Exception:
        pass
