"""Block-size selection for the Pallas kernels.

Two layers:

1. **Heuristic table** (:func:`choose_blocks`) — shape/dtype-keyed rules that
   pick MXU-friendly block sizes without running anything. This is what the
   dispatch layer (``ops.py``) uses by default; it is deterministic at trace
   time so jit caches stay stable.
2. **Measured autotune** (:func:`autotune`) — optional: time a candidate
   sweep for an op instance and cache the winner, keyed by
   ``(op, dims, dtype, backend)``. The cache is consulted by
   :func:`choose_blocks` before the heuristics, and persists to JSON
   (``save_cache``/``load_cache``). Benchmarks run it explicitly; training
   never blocks on measurement.

Persisted caches, loaded lazily on first use (resolving the backend at
import would force JAX runtime initialization as an import side effect) in
priority order (later wins):

1. the **checked-in per-backend-generation cache**
   ``kernels/autotune_cache/<backend_generation()>.json`` (e.g. ``cpu.json``
   for the interpret-mode CI runs, ``tpu-v5p.json`` measured once per chip
   generation and committed);
2. an explicit ``REPRO_AUTOTUNE_CACHE=<path>`` override.

``benchmarks/kernels.py`` run with ``REPRO_AUTOTUNE=1`` re-measures and
rewrites the current backend's checked-in file via :func:`save_cache`.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.tiling import ceil_to
from repro.telemetry.metrics import CounterGroup

# key -> {"bm": ..., ...}
_CACHE: Dict[str, Dict[str, int]] = {}

#: module-global cache/sweep traffic counters ("autotune.*"). Module-level
#: (not run-scoped) because kernel dispatch cannot depend on a run object;
#: an enabled Telemetry adopts this group into its registry, so TrainResult
#: metric snapshots report hit/miss/sweep traffic per run segment.
COUNTERS = CounterGroup(
    "autotune", ("cache_hit", "cache_miss", "sweeps", "sweep_candidates"))


def cache_stats() -> Dict[str, int]:
    """Plain-dict view of the traffic counters (benchmarks, tests)."""
    return dict(COUNTERS)

#: per-backend-generation measured caches checked into the repo
CACHE_DIR = os.path.join(os.path.dirname(__file__), "autotune_cache")


def _active_mesh():
    """The physical mesh of the enclosing ``with mesh:`` context, or None.
    Cheap attribute reads — never initializes a backend by itself."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh is None or not mesh.axis_names or mesh.size <= 1:
        return None
    return mesh


def _local_dims(dims: Dict[str, int], axis_sizes: Dict[str, int]) -> Dict[str, int]:
    """Per-shard dims under a mesh: the token-row dim ``M`` (lora/rmsnorm
    kernels' B·N rows) is split over the data-parallel axes, and the flash
    seq dims over ``model`` when Megatron-SP divides them. Dims that don't
    divide stay global (GSPMD keeps them unsplit or pads — the kernel still
    sees the global block problem). Pure function of its arguments so it is
    testable without a live mesh."""
    dp = 1
    for a in ("pod", "data"):
        dp *= axis_sizes.get(a, 1)
    mp = axis_sizes.get("model", 1)
    out = dict(dims)
    if dp > 1 and "M" in out and out["M"] % dp == 0:
        out["M"] = out["M"] // dp
    for k in ("Nq", "Nk"):
        if mp > 1 and k in out and out[k] % mp == 0:
            out[k] = out[k] // mp
    return out


def _key(op: str, dims: Dict[str, int], dtype, mesh=None) -> str:
    """Cache key: ``op|dims|dtype|backend`` unsharded (the historical format,
    so committed caches keep hitting), with ``|mesh=<axes>`` inserted before
    the backend inside a mesh context — block-size winners depend on the
    per-shard *local* problem, so sharded runs must not reuse (or clobber)
    single-device entries. Keys always end in ``|<backend>``: ``save_cache``
    filters on that suffix. ``mesh`` overrides the ambient-context lookup
    (tests use an AbstractMesh, which has geometry but no ``with`` support
    on this JAX version)."""
    mesh = mesh if mesh is not None else _active_mesh()
    tag = ""
    if mesh is not None:
        sizes = {a: int(mesh.shape[a]) for a in mesh.axis_names}
        dims = _local_dims(dims, sizes)
        tag = "mesh=" + "x".join(f"{a}{n}" for a, n in sizes.items()) + "|"
    d = "/".join(f"{k}={v}" for k, v in sorted(dims.items()))
    return f"{op}|{d}|{jnp.dtype(dtype).name}|{tag}{jax.default_backend()}"


def backend_generation() -> str:
    """Cache-file name for the current accelerator generation: block-size
    winners transfer within a generation (same MXU/VMEM geometry) but not
    across, so e.g. ``tpu-v5p`` and ``tpu-v4`` get separate files; every
    non-TPU backend runs the interpreter and shares one file per platform."""
    if jax.default_backend() == "tpu":
        kind = jax.devices()[0].device_kind       # e.g. "TPU v5p"
        return kind.lower().replace(" ", "-")
    return jax.default_backend()                  # "cpu" / "gpu"


def builtin_cache_path() -> str:
    return os.path.join(CACHE_DIR, backend_generation() + ".json")


# ---------------------------------------------------------------------------
# heuristics
# ---------------------------------------------------------------------------

# Soft VMEM budget per resident block set (bytes). Real VMEM is ~16 MB/core;
# leave room for double buffering and scratch.
_VMEM_BUDGET = 4 << 20


def _pick(n: int, tiers: Iterable[int]) -> int:
    """Largest tier that n fills completely; 128 floor otherwise."""
    for t in tiers:
        if n >= t:
            return t
    return 128


def _matmul_blocks(M: int, K: int, N: int, dtype,
                   w_itemsize: Optional[float] = None) -> Dict[str, int]:
    """``w_itemsize``: bytes/elem of the weight tile when it differs from
    the activation dtype (int8-W0 kernels pass 1, packed int4/nf4 kernels
    0.5 — the smaller tile admits larger K/N blocks for the same VMEM
    residency)."""
    bm = _pick(M, (256,))
    bn = _pick(N, (512, 256))
    bk = _pick(K, (512, 256))
    # shrink until x/w/acc blocks fit the soft budget
    item = jnp.dtype(dtype).itemsize
    w_item = item if w_itemsize is None else w_itemsize
    while (bm * bk * item + bk * bn * w_item + bm * bn * 4) > _VMEM_BUDGET \
            and max(bm, bn, bk) > 128:
        if bk >= bn and bk > 128:
            bk //= 2
        elif bn > 128:
            bn //= 2
        else:
            bm //= 2
    return {"bm": bm, "bn": bn, "bk": bk}


def _heuristic(op: str, dims: Dict[str, int], dtype) -> Dict[str, int]:
    if op in ("lora_fused", "lora_dx"):
        return _matmul_blocks(dims["M"], dims["K"], dims["N"], dtype)
    if op in ("lora_fused_q", "lora_dx_q"):
        return _matmul_blocks(dims["M"], dims["K"], dims["N"], dtype,
                              w_itemsize=1)
    if op in ("lora_fused_q4", "lora_dx_q4"):
        # two nibbles per byte: the W0 tile costs half an int8 tile in VMEM
        # (the unpacked [bk, bn] value tile is transient VPU output)
        return _matmul_blocks(dims["M"], dims["K"], dims["N"], dtype,
                              w_itemsize=0.5)
    if op == "lora_dab":
        # grid is rows-only; x[bm,K] and g[bm,N] are both resident
        item = jnp.dtype(dtype).itemsize
        bm = _pick(dims["M"], (512, 256))
        K, N = dims["K"], dims["N"]
        while bm > 128 and bm * (ceil_to(K, 128) + ceil_to(N, 128)) * item \
                > _VMEM_BUDGET:
            bm //= 2
        return {"bm": bm}
    if op in ("lora_grouped", "lora_grouped_dx",
              "lora_grouped_q", "lora_grouped_dx_q",
              "lora_grouped_q4", "lora_grouped_dx_q4"):
        # bm is layout-determined (the per-group row-tile granularity chosen
        # by the dispatcher before packing); only bn/bk are tunable here.
        w_item = 0.5 if op.endswith("_q4") else 1 if op.endswith("_q") \
            else None
        blk = _matmul_blocks(dims["M"], dims["K"], dims["N"], dtype,
                             w_itemsize=w_item)
        blk.pop("bm", None)
        return blk
    if op == "lora_grouped_dab":
        # same residency shape as lora_dab (x[bm,K] + g[bm,N] resident) but
        # bm is fixed by the group layout, so nothing to choose.
        return {}
    if op == "rmsnorm":
        d = max(dims["d"], 1)
        bm = _pick(dims["M"], (512, 256))
        while bm > 128 and bm * d * 4 > _VMEM_BUDGET:
            bm //= 2
        return {"bm": bm}
    if op == "flash":
        D = dims.get("D", 128)
        bq = _pick(dims["Nq"], (512, 256) if D <= 64 else (256,))
        bk = _pick(dims["Nk"], (512, 256) if D <= 64 else (256,))
        return {"bq": bq, "bk": bk}
    raise ValueError(f"unknown op {op!r}")


def choose_blocks(op: str, dtype=jnp.float32, **dims: int) -> Dict[str, int]:
    """Measured-cache lookup, falling back to the heuristic table."""
    _ensure_loaded()
    hit = _CACHE.get(_key(op, dims, dtype))
    if hit is not None:
        COUNTERS["cache_hit"] += 1
        return dict(hit)
    COUNTERS["cache_miss"] += 1
    return _heuristic(op, dims, dtype)


# ---------------------------------------------------------------------------
# measured autotune
# ---------------------------------------------------------------------------


def _time_once(fn: Callable[[], object]) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def autotune(op: str, run: Callable[[Dict[str, int]], object], *,
             candidates: Iterable[Dict[str, int]],
             dtype=jnp.float32, repeats: int = 3,
             **dims: int) -> Dict[str, int]:
    """Measure ``run(blocks)`` for each candidate, cache and return the best.

    ``run`` must execute the kernel with the given block sizes and return a
    JAX value (used for ``block_until_ready``). Candidates that fail to
    compile/execute (e.g. VMEM overflow on real TPUs) are skipped.

    Timing discipline: the compile call is synced and never timed, and the
    *first timed* iteration is discarded too (dispatch/transfer warm-up) —
    otherwise a candidate can be crowned or buried on compile noise.
    """
    _ensure_loaded()
    COUNTERS["sweeps"] += 1
    best, best_t = None, float("inf")
    for blocks in candidates:
        COUNTERS["sweep_candidates"] += 1
        try:
            jax.block_until_ready(run(blocks))       # compile — never timed
            times = [_time_once(lambda: run(blocks))
                     for _ in range(repeats + 1)]
        except Exception:
            continue
        t = min(times[1:])                           # drop warm-up iteration
        if t < best_t:
            best, best_t = dict(blocks), t
    if best is None:
        best = _heuristic(op, dims, dtype)
    _CACHE[_key(op, dims, dtype)] = dict(best)
    return best


def load_cache(path: str) -> int:
    """Merge a JSON cache file; returns number of entries loaded."""
    with open(path) as f:
        data = json.load(f)
    _CACHE.update({k: {kk: int(vv) for kk, vv in v.items()}
                   for k, v in data.items()})
    return len(data)


def save_cache(path: Optional[str] = None) -> str:
    """Persist the measured cache; default target is the checked-in
    per-backend-generation file (``autotune_cache/<backend>.json``).

    Only the *current* backend's entries are written (keys end in
    ``|<backend>``): the merged in-memory cache may also hold entries
    loaded from other generations' files or a ``REPRO_AUTOTUNE_CACHE``
    override, and those must not leak into this backend's committed file.
    """
    path = path or builtin_cache_path()
    suffix = f"|{jax.default_backend()}"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({k: v for k, v in _CACHE.items() if k.endswith(suffix)},
                  f, indent=1, sort_keys=True)
        f.write("\n")
    return path


_LOADED = False


def _ensure_loaded() -> None:
    """First-use loads: checked-in per-backend cache first, then the
    ``REPRO_AUTOTUNE_CACHE`` override (its entries win the merge). Lazy so
    that importing the package never initializes the JAX runtime (the
    backend name is part of the cache-file name)."""
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    for path in (builtin_cache_path(),
                 os.environ.get("REPRO_AUTOTUNE_CACHE")):
        if path and os.path.exists(path):
            try:
                load_cache(path)
            except Exception:
                pass
