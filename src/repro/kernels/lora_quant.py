"""Quantized-base-weight LoRA Pallas kernels: int8 W0 dequantized in VMEM.

The paper keeps frozen base weights quantized QLoRA-style and dequantizes on
the fly (§4.5); ``core/quant.py`` provides the int8 symmetric per-output-
channel format ``W0 = q · s`` (q int8 [K, N], s f32 [1, N]). These kernels
are the TPU execution path for that format: the int8 tile and its scale row
are the only W0 bytes that ever leave HBM — the bf16/f32 dense W0 exists
only tile-by-tile inside VMEM, never as an HBM array. Relative to the bf16
kernels in ``lora_fused.py`` this halves both the W0 HBM footprint and the
W0 HBM traffic per step.

Dequantization is split across the matmul using the per-output-channel
structure: ``(x @ (q·s))_ij = s_j · Σ_k x_ik q_kj``, so the kernels

* cast the int8 tile to the activation dtype on the VPU in front of the MXU
  (the per-element half of the dequant), and
* apply the scale row once per output tile — on the accumulator in the
  forward (``acc · s`` at the final K step), on the incoming gradient in the
  backward (``(g·s) @ qᵀ``) — instead of per K-step on the weight tile.

Only the two W0-touching ops need quantized variants: the forward and the
``dx`` backward. ``dA``/``dB`` never read W0 (paper A.1 eqs 10/12), so the
fused ``lora_dab`` kernel from ``lora_fused.py`` is reused unchanged.

Wrappers follow the ``tiling.py`` contract: every dim zero-padded to the
block grid and sliced back; padded K rows of q dequantize to zero rows,
padded N columns are sliced off (fwd) or meet zero-padded g columns (dx).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import block_for, pad_dim


def _lora_fused_q_kernel(x_ref, q_ref, s_ref, a_ref, b_ref, o_ref,
                         acc_ref, h_ref, *, scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    # int8 -> activation dtype on the VPU; the scale half of the dequant is
    # deferred to the final K step (it commutes with the K-sum).
    wb = q_ref[...].astype(x_ref.dtype)
    acc_ref[...] += jax.lax.dot(xb, wb, preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[...],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] * s_ref[...] +
                      scale * delta).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _lora_fused_q_call(Mp: int, Kp: int, Np: int, r: int, dtype_name: str,
                       scale: float, bm: int, bn: int, bk: int,
                       interpret: bool):
    n_k = Kp // bk
    return pl.pallas_call(
        functools.partial(_lora_fused_q_kernel, scale=scale, n_k=n_k),
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # q (int8)
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # scale row
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),    # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),    # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.dtype(dtype_name)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),                # W0 accumulator
            pltpu.VMEM((bm, r), jnp.float32),                 # h tile (VMEM!)
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_fused_q(x, q, s, a, b, scale: float = 2.0, *, bm: int = 128,
                 bn: int = 128, bk: int = 128, interpret: bool = False):
    """y = x@(q·s) + s_lora·(x@A)@B.  x:[M,K] q:int8[K,N] s:f32[1,N]
    a:[K,r] b:[r,N] -> [M,N]. Any M/N/K (padded)."""
    M, K = x.shape
    N = q.shape[1]
    r = a.shape[1]
    bm, bn, bk = block_for(M, bm), block_for(N, bn), block_for(K, bk)
    xp = pad_dim(pad_dim(x, bm, 0), bk, 1)
    qp = pad_dim(pad_dim(q, bk, 0), bn, 1)
    sp = pad_dim(s.astype(jnp.float32), bn, 1)
    ap = pad_dim(a, bk, 0)
    bp = pad_dim(b, bn, 1)
    Mp, Kp = xp.shape
    Np = qp.shape[1]
    out = _lora_fused_q_call(Mp, Kp, Np, r, jnp.dtype(x.dtype).name,
                             float(scale), bm, bn, bk,
                             interpret)(xp, qp, sp, ap, bp)
    return out[:M, :N]


def _lora_dx_q_kernel(g_ref, s_ref, qt_ref, dh_ref, at_ref, o_ref, acc_ref,
                      *, n_n: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # g@W0ᵀ = (g·s) @ qᵀ: scale is per-N, i.e. per contraction row of qᵀ,
    # so it folds onto the g tile (VPU) before the int8 tile hits the MXU.
    gs = g_ref[...] * s_ref[...].astype(g_ref.dtype)
    acc_ref[...] += jax.lax.dot(gs, qt_ref[...].astype(g_ref.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot(dh_ref[...], at_ref[...],
                                preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _lora_dx_q_call(Mp: int, Kp: int, Np: int, r: int, dtype_name: str,
                    bm: int, bk: int, bn: int, interpret: bool):
    n_n = Np // bn
    return pl.pallas_call(
        functools.partial(_lora_dx_q_kernel, n_n=n_n),
        grid=(Mp // bm, Kp // bk, n_n),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),   # g
            pl.BlockSpec((1, bn), lambda i, j, n: (0, n)),    # scale row
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, j)),   # qᵀ (int8)
            pl.BlockSpec((bm, r), lambda i, j, n: (i, 0)),    # dh
            pl.BlockSpec((r, bk), lambda i, j, n: (0, j)),    # aᵀ
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Kp), jnp.dtype(dtype_name)),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "bn",
                                             "interpret"))
def lora_dx_q(g, q, s, a, b, scale: float = 2.0, *, bm: int = 128,
              bk: int = 128, bn: int = 128, interpret: bool = False):
    """dx = (s_lora·g)@Bᵀ@Aᵀ + g@(q·s)ᵀ  (A.1 eq 13).  g:[M,N] -> dx:[M,K].

    Like ``lora_dx``: the thin ``dh = s_lora·g@Bᵀ`` matmul stays in jnp; the
    kernel fuses the two large matmuls so ``g`` is read once. The transposed
    int8 table costs half the HBM of the bf16 ``w0.T`` copy in ``lora_dx``.
    """
    M, N = g.shape
    K = q.shape[0]
    bm, bk, bn = block_for(M, bm), block_for(K, bk), block_for(N, bn)
    dh = ((scale * g) @ b.T).astype(g.dtype)        # [M, r] — tiny
    gp = pad_dim(pad_dim(g, bm, 0), bn, 1)
    qtp = pad_dim(pad_dim(q.T, bn, 0), bk, 1)       # int8 [Np, Kp]
    sp = pad_dim(s.astype(jnp.float32), bn, 1)      # [1, Np]
    dhp = pad_dim(dh, bm, 0)
    atp = pad_dim(a.T, bk, 1)                       # [r, Kp]
    Mp, Np = gp.shape
    Kp = qtp.shape[1]
    r = atp.shape[0]
    out = _lora_dx_q_call(Mp, Kp, Np, r, jnp.dtype(g.dtype).name, bm, bk,
                          bn, interpret)(gp, sp, qtp, dhp, atp)
    return out[:M, :K]
