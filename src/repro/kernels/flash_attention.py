"""FlashAttention forward Pallas TPU kernel (paper §2's recompute principle).

Online-softmax over KV blocks with the running (m, l, acc) state in VMEM
scratch; the [Nq, Nk] probability matrix never exists in HBM. Causal /
sliding-window masking is positional (program-id based). The structured
backward (``core/flash.py``) recomputes probabilities tile-wise from the
saved logsumexp — on TPU the forward hot loop is this kernel; the backward
reuses the XLA path (its tiles are already MXU-shaped).

Grid: (B·H, Nq/bq, Nk/bk) with K innermost; accumulators persist across the
K sweep and the output block is written on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, bq: int, bk: int, n_k: int,
                  scale: float):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    s = jax.lax.dot_general(
        q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        bq: int = 512, bk: int = 512,
                        interpret: bool = False):
    """q/k/v: [BH, N, D] (heads pre-flattened, MHA) -> [BH, N, D]."""
    BH, Nq, D = q.shape
    Nk = k.shape[1]
    bq, bk = min(bq, Nq), min(bk, Nk)
    assert Nq % bq == 0 and Nk % bk == 0
    scale = float(1.0 / (D ** 0.5))
    grid = (BH, Nq // bq, Nk // bk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, n_k=Nk // bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Nq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
