"""FlashAttention Pallas TPU kernels (paper §2's recompute principle).

Forward: online-softmax over KV blocks with the running (m, l, acc) state in
VMEM scratch; the [Nq, Nk] probability matrix never exists in HBM. The
per-row logsumexp is emitted alongside the output so the backward pass can
recompute probabilities tile-wise (``p = exp(s − lse)``) instead of saving
them — the same residual contract as the jnp oracle in ``core/flash.py``.

Backward: two kernels factored by which operand stays resident —

* ``_bwd_dq_kernel``  — grid (B·H, Nq/bq, Nk/bk), K innermost; dq accumulates
  in VMEM scratch across the K sweep.
* ``_bwd_dkv_kernel`` — grid (B·Hkv, Nk/bk, G·Nq/bq); a K/V block stays
  resident while all G group members' q/g rows stream past it, so GQA
  head-group reduction happens in VMEM (no H/Hkv-times K/V copy in HBM).

GQA is expressed through BlockSpec index maps: q rows are laid out
[B·H, Nq, D], k/v stay [B·Hkv, Nk, D], and the k/v index map divides the
head program id by the group size — K/V are never repeated.

Causal / sliding-window / padded-length masking is positional (program-id
based); sequence lengths are zero-padded to the block grid and masked with
the static true lengths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import block_for, pad_dim

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal: bool, window: int, nq: int, nk: int):
    """Validity of (q, k) pairs incl. the padded-length guards."""
    ok = (q_pos < nq) & (k_pos < nk)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= q_pos - k_pos < window
    return ok


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                  *, causal: bool, window: int, bq: int, bk: int, n_k: int,
                  nq_valid: int, nk_valid: int, scale: float):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    s = jax.lax.dot_general(
        q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    ok = _mask(q_pos, k_pos, causal=causal, window=window,
               nq=nq_valid, nk=nk_valid)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_per_kv", "interpret",
                                             "return_lse"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        bq: int = 512, bk: int = 512, q_per_kv: int = 1,
                        interpret: bool = False, return_lse: bool = False):
    """q: [B·H, Nq, D]; k/v: [B·Hkv, Nk, D] with H = Hkv·q_per_kv.

    Heads are pre-flattened; consecutive groups of ``q_per_kv`` q heads share
    one kv head (the BlockSpec index map does the division — K/V are never
    repeated). Any Nq/Nk (padded + masked). Returns out or (out, lse).
    """
    BH, Nq, D = q.shape
    Nk = k.shape[1]
    assert BH == k.shape[0] * q_per_kv, (BH, k.shape[0], q_per_kv)
    bq, bk = block_for(Nq, bq), block_for(Nk, bk)
    qp = pad_dim(q, bq, 1)
    kp = pad_dim(k, bk, 1)
    vp = pad_dim(v, bk, 1)
    Nqp, Nkp = qp.shape[1], kp.shape[1]
    scale = float(1.0 / (D ** 0.5))
    G = q_per_kv
    grid = (BH, Nqp // bq, Nkp // bk)
    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, n_k=Nkp // bk,
                          nq_valid=Nq, nk_valid=Nk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Nqp, D), q.dtype),
            jax.ShapeDtypeStruct((BH, Nqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out = out[:, :Nq]
    if return_lse:
        return out, lse[:, :Nq]
    return out


# ---------------------------------------------------------------------------
# backward — probabilities recomputed from the saved logsumexp
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, delta_ref, dq_ref,
                   acc_ref, *, causal: bool, window: int, bq: int, bk: int,
                   n_k: int, nq_valid: int, nk_valid: int, scale: float):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    qb, kb, vb, gb = q_ref[0], k_ref[0], v_ref[0], g_ref[0]
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = _mask(q_pos, k_pos, causal=causal, window=window,
               nq=nq_valid, nk=nk_valid)
    # p via saved lse; explicit zero on masked/padded entries (a fully-masked
    # padded row has lse ≈ NEG_INF, where exp(s − lse) would blow up)
    p = jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0)
    dp = jax.lax.dot_general(gb, vb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)     # eq 18
    ds = p * (dp - delta_ref[0][:, None]) * scale                    # eq 19
    acc_ref[...] += jax.lax.dot(ds.astype(qb.dtype), kb,
                                preferred_element_type=jnp.float32)  # eq 20

    @pl.when(kj == n_k - 1)
    def _finish():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, g_ref, lse_ref, delta_ref, k_ref, v_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, causal: bool,
                    window: int, bq: int, bk: int, n_q: int, n_inner: int,
                    nq_valid: int, nk_valid: int, scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qi = jax.lax.rem(t, n_q)
    kj = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    qb, kb, vb, gb = q_ref[0], k_ref[0], v_ref[0], g_ref[0]
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = _mask(q_pos, k_pos, causal=causal, window=window,
               nq=nq_valid, nk=nk_valid)
    p = jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0)
    pb = p.astype(qb.dtype)
    # dv += pᵀ g  (eq 17, summed over the q heads of this kv group)
    dv_acc[...] += jax.lax.dot_general(pb, gb, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(gb, vb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)     # eq 18
    ds = (p * (dp - delta_ref[0][:, None]) * scale).astype(qb.dtype)
    # dk += dsᵀ q  (eq 21)
    dk_acc[...] += jax.lax.dot_general(ds, qb, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(t == n_inner - 1)
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_per_kv", "interpret"))
def flash_attention_bwd(q, k, v, out, lse, g, *, causal: bool = True,
                        window: int = 0, bq: int = 512, bk: int = 512,
                        q_per_kv: int = 1, interpret: bool = False):
    """(dq, dk, dv) from the saved (out, lse) residuals.

    q/g/out: [B·H, Nq, D]; k/v: [B·Hkv, Nk, D]; lse: [B·H, Nq] (f32).
    dk/dv come back group-summed at kv-head layout [B·Hkv, Nk, D].
    """
    BH, Nq, D = q.shape
    BHkv, Nk = k.shape[0], k.shape[1]
    assert BH == BHkv * q_per_kv
    bq, bk = block_for(Nq, bq), block_for(Nk, bk)
    scale = float(1.0 / (D ** 0.5))
    G = q_per_kv

    # flash softmax correction term: delta_i = Σ_d g_i·out_i (A.2 eq 19's
    # sum(dprobs ⊙ probs) in tile-local form) — one cheap rowwise reduction
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), -1)

    qp = pad_dim(q, bq, 1)
    gp = pad_dim(g.astype(q.dtype), bq, 1)
    lsep = pad_dim(lse, bq, 1)
    deltap = pad_dim(delta, bq, 1)
    kp = pad_dim(k, bk, 1)
    vp = pad_dim(v, bk, 1)
    Nqp, Nkp = qp.shape[1], kp.shape[1]
    n_q, n_k = Nqp // bq, Nkp // bk

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, n_k=n_k,
                          nq_valid=Nq, nk_valid=Nk, scale=scale),
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),      # q
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // G, j, 0)),  # k
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // G, j, 0)),  # v
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),      # g
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),            # lse
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),            # delta
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Nqp, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, gp, lsep, deltap)

    n_inner = G * n_q
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, n_q=n_q, n_inner=n_inner,
                          nq_valid=Nq, nk_valid=Nk, scale=scale),
        grid=(BHkv, n_k, n_inner),
        in_specs=[
            pl.BlockSpec((1, bq, D),
                         lambda b, j, t: (b * G + t // n_q, t % n_q, 0)),  # q
            pl.BlockSpec((1, bq, D),
                         lambda b, j, t: (b * G + t // n_q, t % n_q, 0)),  # g
            pl.BlockSpec((1, bq),
                         lambda b, j, t: (b * G + t // n_q, t % n_q)),  # lse
            pl.BlockSpec((1, bq),
                         lambda b, j, t: (b * G + t // n_q, t % n_q)),  # delta
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),        # k
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),        # v
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHkv, Nkp, D), k.dtype),
            jax.ShapeDtypeStruct((BHkv, Nkp, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, gp, lsep, deltap, kp, vp)

    return dq[:, :Nq], dk[:, :Nk], dv[:, :Nk]
