"""FlashAttention Pallas TPU kernels (paper §2's recompute principle) on
**sparse tile grids** with optional **in-kernel RoPE**.

Forward: online-softmax over KV blocks with the running (m, l, acc) state in
VMEM scratch; the [Nq, Nk] probability matrix never exists in HBM. The
per-row logsumexp is emitted alongside the output so the backward pass can
recompute probabilities tile-wise (``p = exp(s − lse)``) instead of saving
them — the same residual contract as the jnp oracle in ``core/flash.py``.

Sparse grids: causal / sliding-window / padded-length masking is known at
trace time, so instead of sweeping the dense ``n_q × n_k`` tile grid and
masking dead tiles, every kernel iterates a *flat* grid over exactly the
live tiles. The flat-step → (q_block, k_block) mapping is an int32 schedule
(``tiling.flash_schedule``) handed to the kernel via scalar prefetch; the
BlockSpec index maps read it to pick each step's HBM tiles. Tiles whose
every (q, k) pair is valid are flagged *interior* and skip mask
construction entirely; only boundary tiles (diagonal, window edge, padded
edge) build the positional mask. ``sparse=False`` runs the same kernels on
the dense schedule — the reference grid for tests and benchmarks.

Backward: two kernels factored by which operand stays resident —

* ``_bwd_dq_kernel``  — flat grid over the row-major schedule; dq
  accumulates in VMEM scratch across each q row's live k blocks.
* ``_bwd_dkv_kernel`` — flat grid over the *transposed* (k-outer) schedule
  (``tiling.flash_schedule_kv``); a K/V block stays resident while all G
  group members' live q/g rows stream past it, so GQA head-group reduction
  happens in VMEM (no H/Hkv-times K/V copy in HBM).

GQA is expressed through the schedule + BlockSpec index maps: q rows are
laid out [B·H, Nq, D], k/v stay [B·Hkv, Nk, D], and the k/v index map
divides the head program id by the group size — K/V are never repeated.

Fused RoPE: with ``rope=(cos, sin)`` ([N, D/2] f32 tables), q/k tiles are
rotated in VMEM right after load — the rotated q/k never round-trip through
HBM — and the backward counter-rotates dq/dk (rotation is orthogonal:
dx = R₋θ(dy)) before the final write. Rows that attend to no key (fully
masked, e.g. causal+window with Nq > Nk+window) produce exactly 0 output
and a −∞ logsumexp in both sparse and dense modes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (block_for, flash_schedule,
                                  flash_schedule_kv, pad_dim)

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal: bool, window: int, nq: int, nk: int):
    """Validity of (q, k) pairs incl. the padded-length guards."""
    ok = (q_pos < nq) & (k_pos < nk)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= q_pos - k_pos < window
    return ok


def _rot(x, cos, sin):
    """Rotate the half-split last dim: RoPE's R_θ (f32 compute).
    ``_rot(g, cos, -sin)`` is the inverse/transpose R₋θ (backward)."""
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           -1).astype(x.dtype)


def _pad_table(t, mult: int, value: float):
    """Pad a [N, half] rope table along rows with the identity rotation
    (cos=1, sin=0) so padded q/k rows stay bit-identical to the unroped
    zero padding."""
    n = t.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return t
    return jnp.pad(t, ((0, pad), (0, 0)), constant_values=value)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(qi_ref, kj_ref, int_ref, q_ref, k_ref, v_ref, *rest,
                causal: bool, window: int, bq: int, bk: int, nq_valid: int,
                nk_valid: int, scale: float, fuse_rope: bool):
    if fuse_rope:
        (cq_ref, sq_ref, ck_ref, sk_ref,
         o_ref, lse_ref, m_ref, l_ref, acc_ref) = rest
    else:
        o_ref, lse_ref, m_ref, l_ref, acc_ref = rest

    t = pl.program_id(1)
    T = pl.num_programs(1)
    row, col = qi_ref[t], kj_ref[t]
    first = jnp.logical_or(t == 0, row != qi_ref[jnp.maximum(t - 1, 0)])
    last = jnp.logical_or(t == T - 1,
                          row != qi_ref[jnp.minimum(t + 1, T - 1)])

    @pl.when(first)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb, kb = q_ref[0], k_ref[0]
    if fuse_rope:
        qb = _rot(qb, cq_ref[...], sq_ref[...])
        kb = _rot(kb, ck_ref[...], sk_ref[...])
    s = jax.lax.dot_general(
        qb, kb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    def _accum(s):
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    interior = int_ref[t] == 1

    @pl.when(interior)
    def _interior():        # fully valid tile: no mask is ever built
        _accum(s)

    @pl.when(jnp.logical_not(interior))
    def _boundary():        # diagonal / window-edge / padded-edge tile
        q_pos = row * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = col * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = _mask(q_pos, k_pos, causal=causal, window=window,
                   nq=nq_valid, nk=nk_valid)
        _accum(jnp.where(ok, s, NEG_INF))

    @pl.when(last)
    def _finish():
        # rows that never saw an unmasked key keep m == NEG_INF: emit exact
        # zeros + a -inf-like lse (the bwd's masked p is 0 regardless)
        never = m_ref[...] <= NEG_INF * 0.5
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = jnp.where(never, 0.0,
                             acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = jnp.where(never[:, 0], NEG_INF,
                               (m_ref[...] + jnp.log(l))[:, 0])


@functools.lru_cache(maxsize=None)
def _fwd_call(BH: int, Nqp: int, Nkp: int, D: int, dtype_name: str, bq: int,
              bk: int, causal: bool, window: int, nq: int, nk: int, G: int,
              fuse_rope: bool, sparse: bool, interpret: bool):
    """Construct (pallas_call, schedule) once per static signature — repeated
    non-jit calls (benchmarks, tests) reuse the built closure."""
    qi, kj, it = flash_schedule(Nqp // bq, Nkp // bk, bq, bk, causal,
                                window, nq, nk, sparse)
    dtype = jnp.dtype(dtype_name)
    half = D // 2
    kern = functools.partial(
        _fwd_kernel, causal=causal, window=window, bq=bq, bk=bk,
        nq_valid=nq, nk_valid=nk, scale=float(1.0 / (D ** 0.5)),
        fuse_rope=fuse_rope)
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda b, t, qi, kj, it: (b, qi[t], 0)),
        pl.BlockSpec((1, bk, D),
                     lambda b, t, qi, kj, it: (b // G, kj[t], 0)),
        pl.BlockSpec((1, bk, D),
                     lambda b, t, qi, kj, it: (b // G, kj[t], 0)),
    ]
    if fuse_rope:
        in_specs += [
            pl.BlockSpec((bq, half), lambda b, t, qi, kj, it: (qi[t], 0)),
            pl.BlockSpec((bq, half), lambda b, t, qi, kj, it: (qi[t], 0)),
            pl.BlockSpec((bk, half), lambda b, t, qi, kj, it: (kj[t], 0)),
            pl.BlockSpec((bk, half), lambda b, t, qi, kj, it: (kj[t], 0)),
        ]
    call = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(BH, len(qi)),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, bq, D),
                             lambda b, t, qi, kj, it: (b, qi[t], 0)),
                pl.BlockSpec((1, bq), lambda b, t, qi, kj, it: (b, qi[t])),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),   # running max
                pltpu.VMEM((bq, 1), jnp.float32),   # running sum
                pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((BH, Nqp, D), dtype),
            jax.ShapeDtypeStruct((BH, Nqp), jnp.float32),
        ],
        interpret=interpret,
    )
    return call, (qi, kj, it)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_per_kv", "interpret",
                                             "return_lse", "sparse"))
def flash_attention_fwd(q, k, v, rope=None, *, causal: bool = True,
                        window: int = 0, bq: int = 512, bk: int = 512,
                        q_per_kv: int = 1, interpret: bool = False,
                        return_lse: bool = False, sparse: bool = True):
    """q: [B·H, Nq, D]; k/v: [B·Hkv, Nk, D] with H = Hkv·q_per_kv.

    Heads are pre-flattened; consecutive groups of ``q_per_kv`` q heads share
    one kv head (the BlockSpec index map does the division — K/V are never
    repeated). Any Nq/Nk (padded + masked). ``rope=(cos, sin)`` ([N, D/2]
    f32, Nq == Nk) rotates q/k tiles in VMEM. Returns out or (out, lse).
    """
    BH, Nq, D = q.shape
    Nk = k.shape[1]
    assert BH == k.shape[0] * q_per_kv, (BH, k.shape[0], q_per_kv)
    bq, bk = block_for(Nq, bq), block_for(Nk, bk)
    qp = pad_dim(q, bq, 1)
    kp = pad_dim(k, bk, 1)
    vp = pad_dim(v, bk, 1)
    Nqp, Nkp = qp.shape[1], kp.shape[1]
    call, sched = _fwd_call(BH, Nqp, Nkp, D, jnp.dtype(q.dtype).name, bq, bk,
                            causal, window, Nq, Nk, q_per_kv,
                            rope is not None, sparse, interpret)
    operands = [qp, kp, vp]
    if rope is not None:
        cos, sin = rope
        assert Nq == Nk and cos.shape == (Nq, D // 2), (cos.shape, Nq, D)
        # the table is read through both (bq, ·) and (bk, ·) blocks — pad to
        # the coarser grid so every block index stays in bounds
        tb = max(bq, bk)
        cosp = _pad_table(cos.astype(jnp.float32), tb, 1.0)
        sinp = _pad_table(sin.astype(jnp.float32), tb, 0.0)
        operands += [cosp, sinp, cosp, sinp]
    out, lse = call(*sched, *operands)
    out = out[:, :Nq]
    if return_lse:
        return out, lse[:, :Nq]
    return out


# ---------------------------------------------------------------------------
# backward — probabilities recomputed from the saved logsumexp
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(qi_ref, kj_ref, int_ref, q_ref, k_ref, v_ref, g_ref,
                   lse_ref, delta_ref, *rest, causal: bool, window: int,
                   bq: int, bk: int, nq_valid: int, nk_valid: int,
                   scale: float, fuse_rope: bool):
    if fuse_rope:
        cq_ref, sq_ref, ck_ref, sk_ref, dq_ref, acc_ref = rest
    else:
        dq_ref, acc_ref = rest

    t = pl.program_id(1)
    T = pl.num_programs(1)
    row, col = qi_ref[t], kj_ref[t]
    first = jnp.logical_or(t == 0, row != qi_ref[jnp.maximum(t - 1, 0)])
    last = jnp.logical_or(t == T - 1,
                          row != qi_ref[jnp.minimum(t + 1, T - 1)])

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb, kb, vb, gb = q_ref[0], k_ref[0], v_ref[0], g_ref[0]
    if fuse_rope:
        qb = _rot(qb, cq_ref[...], sq_ref[...])
        kb = _rot(kb, ck_ref[...], sk_ref[...])
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    def _accum(p):
        dp = jax.lax.dot_general(gb, vb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # eq 18
        ds = p * (dp - delta_ref[0][:, None]) * scale                 # eq 19
        acc_ref[...] += jax.lax.dot(ds.astype(qb.dtype), kb,
                                    preferred_element_type=jnp.float32)

    interior = int_ref[t] == 1

    @pl.when(interior)
    def _interior():
        _accum(jnp.exp(s - lse_ref[0][:, None]))

    @pl.when(jnp.logical_not(interior))
    def _boundary():
        q_pos = row * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = col * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = _mask(q_pos, k_pos, causal=causal, window=window,
                   nq=nq_valid, nk=nk_valid)
        # p via saved lse; explicit zero on masked/padded entries (a fully-
        # masked row has lse = NEG_INF, where exp(s − lse) would blow up)
        _accum(jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0))

    @pl.when(last)
    def _finish():
        acc = acc_ref[...]
        if fuse_rope:   # d q = R₋θ(d q_rot)  — rotation is orthogonal
            acc = _rot(acc, cq_ref[...], -sq_ref[...])
        dq_ref[0] = acc.astype(dq_ref.dtype)


def _bwd_dkv_kernel(kjs_ref, gh_ref, qis_ref, int_ref, q_ref, g_ref, lse_ref,
                    delta_ref, k_ref, v_ref, *rest, causal: bool,
                    window: int, bq: int, bk: int, nq_valid: int,
                    nk_valid: int, scale: float, fuse_rope: bool):
    if fuse_rope:
        cq_ref, sq_ref, ck_ref, sk_ref, dk_ref, dv_ref, dk_acc, dv_acc = rest
    else:
        dk_ref, dv_ref, dk_acc, dv_acc = rest

    t = pl.program_id(1)
    T = pl.num_programs(1)
    col, row = kjs_ref[t], qis_ref[t]
    first = jnp.logical_or(t == 0, col != kjs_ref[jnp.maximum(t - 1, 0)])
    last = jnp.logical_or(t == T - 1,
                          col != kjs_ref[jnp.minimum(t + 1, T - 1)])

    @pl.when(first)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qb, kb, vb, gb = q_ref[0], k_ref[0], v_ref[0], g_ref[0]
    if fuse_rope:
        qb = _rot(qb, cq_ref[...], sq_ref[...])
        kb = _rot(kb, ck_ref[...], sk_ref[...])
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    def _accum(p):
        pb = p.astype(qb.dtype)
        # dv += pᵀ g  (eq 17, summed over the q heads of this kv group)
        dv_acc[...] += jax.lax.dot_general(pb, gb, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(gb, vb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # eq 18
        ds = (p * (dp - delta_ref[0][:, None]) * scale).astype(qb.dtype)
        # dk += dsᵀ q  (eq 21)
        dk_acc[...] += jax.lax.dot_general(ds, qb, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    interior = int_ref[t] == 1

    @pl.when(interior)
    def _interior():
        _accum(jnp.exp(s - lse_ref[0][:, None]))

    @pl.when(jnp.logical_not(interior))
    def _boundary():
        q_pos = row * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = col * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = _mask(q_pos, k_pos, causal=causal, window=window,
                   nq=nq_valid, nk=nk_valid)
        _accum(jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0))

    @pl.when(last)
    def _finish():
        dk = dk_acc[...]
        if fuse_rope:   # d k = R₋θ(d k_rot)
            dk = _rot(dk, ck_ref[...], -sk_ref[...])
        dk_ref[0] = dk.astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.lru_cache(maxsize=None)
def _bwd_dq_call(BH: int, Nqp: int, Nkp: int, D: int, dtype_name: str,
                 bq: int, bk: int, causal: bool, window: int, nq: int,
                 nk: int, G: int, fuse_rope: bool, sparse: bool,
                 interpret: bool):
    qi, kj, it = flash_schedule(Nqp // bq, Nkp // bk, bq, bk, causal,
                                window, nq, nk, sparse)
    dtype = jnp.dtype(dtype_name)
    half = D // 2
    kern = functools.partial(
        _bwd_dq_kernel, causal=causal, window=window, bq=bq, bk=bk,
        nq_valid=nq, nk_valid=nk, scale=float(1.0 / (D ** 0.5)),
        fuse_rope=fuse_rope)
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda b, t, qi, kj, it: (b, qi[t], 0)),
        pl.BlockSpec((1, bk, D),
                     lambda b, t, qi, kj, it: (b // G, kj[t], 0)),   # k
        pl.BlockSpec((1, bk, D),
                     lambda b, t, qi, kj, it: (b // G, kj[t], 0)),   # v
        pl.BlockSpec((1, bq, D), lambda b, t, qi, kj, it: (b, qi[t], 0)),  # g
        pl.BlockSpec((1, bq), lambda b, t, qi, kj, it: (b, qi[t])),  # lse
        pl.BlockSpec((1, bq), lambda b, t, qi, kj, it: (b, qi[t])),  # delta
    ]
    if fuse_rope:
        in_specs += [
            pl.BlockSpec((bq, half), lambda b, t, qi, kj, it: (qi[t], 0)),
            pl.BlockSpec((bq, half), lambda b, t, qi, kj, it: (qi[t], 0)),
            pl.BlockSpec((bk, half), lambda b, t, qi, kj, it: (kj[t], 0)),
            pl.BlockSpec((bk, half), lambda b, t, qi, kj, it: (kj[t], 0)),
        ]
    call = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(BH, len(qi)),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bq, D),
                                   lambda b, t, qi, kj, it: (b, qi[t], 0)),
            scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, Nqp, D), dtype),
        interpret=interpret,
    )
    return call, (qi, kj, it)


@functools.lru_cache(maxsize=None)
def _bwd_dkv_call(BHkv: int, Nqp: int, Nkp: int, D: int, dtype_q: str,
                  dtype_k: str, dtype_v: str, bq: int, bk: int, causal: bool,
                  window: int, nq: int, nk: int, G: int, fuse_rope: bool,
                  sparse: bool, interpret: bool):
    kjs, gh, qis, it = flash_schedule_kv(Nqp // bq, Nkp // bk, bq, bk,
                                         causal, window, nq, nk, G, sparse)
    half = D // 2
    kern = functools.partial(
        _bwd_dkv_kernel, causal=causal, window=window, bq=bq, bk=bk,
        nq_valid=nq, nk_valid=nk, scale=float(1.0 / (D ** 0.5)),
        fuse_rope=fuse_rope)
    qmap = lambda b, t, kjs, gh, qis, it: (b * G + gh[t], qis[t], 0)
    rmap = lambda b, t, kjs, gh, qis, it: (b * G + gh[t], qis[t])
    kvmap = lambda b, t, kjs, gh, qis, it: (b, kjs[t], 0)
    in_specs = [
        pl.BlockSpec((1, bq, D), qmap),        # q
        pl.BlockSpec((1, bq, D), qmap),        # g
        pl.BlockSpec((1, bq), rmap),           # lse
        pl.BlockSpec((1, bq), rmap),           # delta
        pl.BlockSpec((1, bk, D), kvmap),       # k
        pl.BlockSpec((1, bk, D), kvmap),       # v
    ]
    if fuse_rope:
        in_specs += [
            pl.BlockSpec((bq, half),
                         lambda b, t, kjs, gh, qis, it: (qis[t], 0)),
            pl.BlockSpec((bq, half),
                         lambda b, t, kjs, gh, qis, it: (qis[t], 0)),
            pl.BlockSpec((bk, half),
                         lambda b, t, kjs, gh, qis, it: (kjs[t], 0)),
            pl.BlockSpec((bk, half),
                         lambda b, t, kjs, gh, qis, it: (kjs[t], 0)),
        ]
    call = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(BHkv, len(kjs)),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, bk, D), kvmap),
                pl.BlockSpec((1, bk, D), kvmap),
            ],
            scratch_shapes=[
                pltpu.VMEM((bk, D), jnp.float32),
                pltpu.VMEM((bk, D), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((BHkv, Nkp, D), jnp.dtype(dtype_k)),
            jax.ShapeDtypeStruct((BHkv, Nkp, D), jnp.dtype(dtype_v)),
        ],
        interpret=interpret,
    )
    return call, (kjs, gh, qis, it)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_per_kv", "interpret",
                                             "sparse"))
def flash_attention_bwd(q, k, v, out, lse, g, rope=None, *,
                        causal: bool = True, window: int = 0, bq: int = 512,
                        bk: int = 512, q_per_kv: int = 1,
                        interpret: bool = False, sparse: bool = True):
    """(dq, dk, dv) from the saved (out, lse) residuals.

    q/g/out: [B·H, Nq, D]; k/v: [B·Hkv, Nk, D]; lse: [B·H, Nq] (f32).
    dk/dv come back group-summed at kv-head layout [B·Hkv, Nk, D]. With
    ``rope=(cos, sin)`` the kernels rotate q/k on load (as the forward did)
    and counter-rotate dq/dk before the final write.
    """
    BH, Nq, D = q.shape
    BHkv, Nk = k.shape[0], k.shape[1]
    assert BH == BHkv * q_per_kv
    bq, bk = block_for(Nq, bq), block_for(Nk, bk)
    G = q_per_kv

    # flash softmax correction term: delta_i = Σ_d g_i·out_i (A.2 eq 19's
    # sum(dprobs ⊙ probs) in tile-local form) — one cheap rowwise reduction
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), -1)

    qp = pad_dim(q, bq, 1)
    gp = pad_dim(g.astype(q.dtype), bq, 1)
    lsep = pad_dim(lse, bq, 1)
    deltap = pad_dim(delta, bq, 1)
    kp = pad_dim(k, bk, 1)
    vp = pad_dim(v, bk, 1)
    Nqp, Nkp = qp.shape[1], kp.shape[1]

    rope_ops = []
    if rope is not None:
        cos, sin = rope
        assert Nq == Nk and cos.shape == (Nq, D // 2), (cos.shape, Nq, D)
        tb = max(bq, bk)    # read through (bq, ·) and (bk, ·) blocks alike
        cosp = _pad_table(cos.astype(jnp.float32), tb, 1.0)
        sinp = _pad_table(sin.astype(jnp.float32), tb, 0.0)
        rope_ops = [cosp, sinp, cosp, sinp]

    dq_call, dq_sched = _bwd_dq_call(
        BH, Nqp, Nkp, D, jnp.dtype(q.dtype).name, bq, bk, causal, window,
        Nq, Nk, G, rope is not None, sparse, interpret)
    dq = dq_call(*dq_sched, qp, kp, vp, gp, lsep, deltap, *rope_ops)

    dkv_call, dkv_sched = _bwd_dkv_call(
        BHkv, Nqp, Nkp, D, jnp.dtype(q.dtype).name, jnp.dtype(k.dtype).name,
        jnp.dtype(v.dtype).name, bq, bk, causal, window, Nq, Nk, G,
        rope is not None, sparse, interpret)
    dk, dv = dkv_call(*dkv_sched, qp, gp, lsep, deltap, kp, vp, *rope_ops)

    return dq[:, :Nq], dk[:, :Nk], dv[:, :Nk]
