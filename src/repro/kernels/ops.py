"""Jit'd public wrappers over the Pallas kernels with shape plumbing and a
custom_vjp that composes kernel forward passes with the paper's structured
backward rules. On non-TPU backends pass ``interpret=True`` (tests do); the
wrappers keep the same semantics as the pure-jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import lora_fused as _lf
from repro.kernels import rmsnorm as _rn
from repro.kernels import flash_attention as _fa


def _flat(x):
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
# LoRA linear: Pallas fwd (h in VMEM) + structured bwd (h recomputed; dx via
# the fused dx kernel; dA/dB thin matmuls)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def lora_linear_kernel(x, w0, a, b, scale: float = 2.0,
                       interpret: bool = False):
    """y = x@W0 + s·(x@A)@B with [..., K] inputs."""
    lead = x.shape[:-1]
    y = _lf.lora_fused(_flat(x), w0, a, b, scale, interpret=interpret)
    return y.reshape(*lead, w0.shape[1])


def _fwd(x, w0, a, b, scale, interpret):
    return lora_linear_kernel(x, w0, a, b, scale, interpret), (x, w0, a, b)


def _bwd(scale, interpret, res, g):
    x, w0, a, b = res
    lead = x.shape[:-1]
    g2 = _flat(g).astype(x.dtype)
    x2 = _flat(x)
    dx = _lf.lora_dx(g2, w0, a, b, scale, interpret=interpret)
    h = x2 @ a                                   # recomputed (paper §4.1)
    db = h.T @ (scale * g2)
    dh = (scale * g2) @ b.T
    da = x2.T @ dh
    return (dx.reshape(*lead, w0.shape[0]), jnp.zeros_like(w0),
            da.astype(a.dtype), db.astype(b.dtype))


lora_linear_kernel.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm_kernel(x, w, eps: float = 1e-6, interpret: bool = False):
    lead = x.shape[:-1]
    return _rn.rmsnorm(_flat(x), w, eps, interpret=interpret).reshape(x.shape)


def _rn_fwd(x, w, eps, interpret):
    return rmsnorm_kernel(x, w, eps, interpret), (x, w)


def _rn_bwd(eps, interpret, res, g):
    x, w = res
    dx, dw = _rn.rmsnorm_bwd(_flat(x), w, _flat(g), eps, interpret=interpret)
    return dx.reshape(x.shape), dw


rmsnorm_kernel.defvjp(_rn_fwd, _rn_bwd)


# ---------------------------------------------------------------------------
# Flash attention (forward kernel; GQA handled by head repeat in the wrapper)
# ---------------------------------------------------------------------------


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q: [B,H,N,D]; k/v: [B,Hkv,Nk,D] -> [B,H,N,D]."""
    B, H, Nq, D = q.shape
    Hkv, Nk = k.shape[1], k.shape[2]
    if Hkv != H:  # GQA: expand kv heads (kernel-side ragged grouping is a
        rep = H // Hkv  # perf follow-up; wrapper keeps semantics exact)
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    out = _fa.flash_attention_fwd(
        q.reshape(B * H, Nq, D), k.reshape(B * H, Nk, D),
        v.reshape(B * H, Nk, D), causal=causal, window=window,
        bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, Nq, D)
