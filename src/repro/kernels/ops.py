"""Kernel dispatch layer: the single entry point for the ``pallas``
ExecutionPolicy backend.

``models/layers.py`` (and through it every model family, ``core/mesp.py``,
``launch/train.py`` and the benchmarks) routes trainable-path ops here when
``policy.backend == "pallas"`` is selected. Each public dispatcher:

* checks :func:`*_supported` for the given operands and falls back to the
  structured jnp path (``core/structured``) on unsupported shapes — per-op,
  so one unsupported op never drags the whole block off the kernel path
  (MoE per-expert [E,·,·] linears have their own grouped kernel family
  below and no longer fall back);
* picks block sizes from ``kernels/autotune.py`` (heuristic table, optionally
  overridden by a measured cache);
* runs the Pallas kernel with ``interpret=True`` automatically on non-TPU
  backends (override with ``REPRO_PALLAS_INTERPRET=0/1``), so the same
  training code runs on CPU tests and TPU production.

The custom_vjps below compose the kernel forwards with kernel backwards that
follow the paper's structured rules: ``h``/probabilities are *recomputed* in
the backward (from ``x`` / the saved logsumexp), never stored.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import quant, structured
from repro.kernels import autotune
from repro.kernels import lora_fused as _lf
from repro.kernels import lora_grouped as _lg
from repro.kernels import lora_pack4 as _lp4
from repro.kernels import lora_quant as _lq
from repro.kernels import rmsnorm as _rn
from repro.kernels import flash_attention as _fa
from repro.kernels import rope as _rope
from repro.kernels import tiling

# Below this many query rows the dense structured sdpa beats the kernel's
# padding + grid overhead (and is easier to cross-check).
PALLAS_ATTN_MIN_SEQ = 64


def _flat(x):
    return x.reshape(-1, x.shape[-1])


def pallas_interpret() -> bool:
    """True when kernels must run under the Pallas interpreter (non-TPU)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _resolve_interpret(policy, interpret):
    """Dispatcher interpret resolution: explicit kwarg > policy override
    (``ExecutionPolicy.interpret``) > backend autodetect."""
    if interpret is not None:
        return interpret
    if policy is not None and policy.interpret is not None:
        return policy.interpret
    return pallas_interpret()


# ---------------------------------------------------------------------------
# LoRA linear: Pallas fwd (h in VMEM) + Pallas bwd (h recomputed; dx via the
# fused dx kernel; dA/dB via the fused one-pass dab kernel)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def lora_linear_kernel(x, w0, a, b, scale: float = 2.0,
                       interpret: bool = False):
    """y = x@W0 + s·(x@A)@B with [..., K] inputs. Any shapes (padded)."""
    lead = x.shape[:-1]
    x2 = _flat(x)
    blk = autotune.choose_blocks("lora_fused", x.dtype, M=x2.shape[0],
                                 K=x2.shape[1], N=w0.shape[1])
    y = _lf.lora_fused(x2, w0, a, b, scale, interpret=interpret, **blk)
    return y.reshape(*lead, w0.shape[1])


def _fwd(x, w0, a, b, scale, interpret):
    return lora_linear_kernel(x, w0, a, b, scale, interpret), (x, w0, a, b)


def _bwd(scale, interpret, res, g):
    x, w0, a, b = res
    lead = x.shape[:-1]
    g2 = _flat(g).astype(x.dtype)
    x2 = _flat(x)
    M, K = x2.shape
    N = w0.shape[1]
    dx = _lf.lora_dx(g2, w0, a, b, scale, interpret=interpret,
                     **autotune.choose_blocks("lora_dx", x.dtype,
                                              M=M, K=K, N=N))
    # one fused pass over x/g: h recomputed tile-wise in VMEM (paper §4.1)
    da, db = _lf.lora_dab(x2, g2, a, b, scale, interpret=interpret,
                          **autotune.choose_blocks("lora_dab", x.dtype,
                                                   M=M, K=K, N=N))
    return (dx.reshape(*lead, w0.shape[0]), jnp.zeros_like(w0), da, db)


lora_linear_kernel.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# Quantized-W0 LoRA linear: int8 q + per-output-channel scale dequantized in
# VMEM (kernels/lora_quant.py). Forward and dx never materialize a dense W0
# in HBM; dA/dB reuse the unquantized fused dab kernel (they don't read W0).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def lora_linear_kernel_q(x, q, s, a, b, scale: float = 2.0,
                         interpret: bool = False):
    """y = x@(q·s) + s_lora·(x@A)@B. q: int8 [K,N]; s: f32 [1,N]."""
    lead = x.shape[:-1]
    x2 = _flat(x)
    blk = autotune.choose_blocks("lora_fused_q", x.dtype, M=x2.shape[0],
                                 K=x2.shape[1], N=q.shape[1])
    y = _lq.lora_fused_q(x2, q, s, a, b, scale, interpret=interpret, **blk)
    return y.reshape(*lead, q.shape[1])


def _fwd_q(x, q, s, a, b, scale, interpret):
    return lora_linear_kernel_q(x, q, s, a, b, scale, interpret), (x, q, s,
                                                                   a, b)


def _bwd_q(scale, interpret, res, g):
    x, q, s, a, b = res
    lead = x.shape[:-1]
    g2 = _flat(g).astype(x.dtype)
    x2 = _flat(x)
    M, K = x2.shape
    N = q.shape[1]
    dx = _lq.lora_dx_q(g2, q, s, a, b, scale, interpret=interpret,
                       **autotune.choose_blocks("lora_dx_q", x.dtype,
                                                M=M, K=K, N=N))
    da, db = _lf.lora_dab(x2, g2, a, b, scale, interpret=interpret,
                          **autotune.choose_blocks("lora_dab", x.dtype,
                                                   M=M, K=K, N=N))
    # q is int8 (float0 cotangent); s is frozen alongside it
    return (dx.reshape(*lead, K), structured._zero_cot(q),
            jnp.zeros_like(s), da, db)


lora_linear_kernel_q.defvjp(_fwd_q, _bwd_q)


# ---------------------------------------------------------------------------
# Packed-4-bit-W0 LoRA linear: two nibbles per byte unpacked in VMEM
# (kernels/lora_pack4.py, int4 sign-extend / nf4 codebook). Forward and dx
# read only the packed bytes + scale row from HBM; dA/dB reuse the
# unquantized fused dab kernel (they don't read W0).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def lora_linear_kernel_p4(x, q4, s, a, b, scale: float = 2.0,
                          interpret: bool = False, method: str = "int4"):
    """y = x@dequant(q4)·s + s_lora·(x@A)@B. q4: uint8 [ceil(K/2),N]."""
    lead = x.shape[:-1]
    x2 = _flat(x)
    blk = autotune.choose_blocks("lora_fused_q4", x.dtype, M=x2.shape[0],
                                 K=x2.shape[1], N=q4.shape[1])
    y = _lp4.lora_fused_q4(x2, q4, s, a, b, scale, method=method,
                           interpret=interpret, **blk)
    return y.reshape(*lead, q4.shape[1])


def _fwd_p4(x, q4, s, a, b, scale, interpret, method):
    return (lora_linear_kernel_p4(x, q4, s, a, b, scale, interpret, method),
            (x, q4, s, a, b))


def _bwd_p4(scale, interpret, method, res, g):
    x, q4, s, a, b = res
    lead = x.shape[:-1]
    g2 = _flat(g).astype(x.dtype)
    x2 = _flat(x)
    M, K = x2.shape
    N = q4.shape[1]
    dx = _lp4.lora_dx_q4(g2, q4, s, a, b, scale, method=method,
                         interpret=interpret,
                         **autotune.choose_blocks("lora_dx_q4", x.dtype,
                                                  M=M, K=K, N=N))
    da, db = _lf.lora_dab(x2, g2, a, b, scale, interpret=interpret,
                          **autotune.choose_blocks("lora_dab", x.dtype,
                                                   M=M, K=K, N=N))
    # q4 is uint8 (float0 cotangent); s is frozen alongside it
    return (dx.reshape(*lead, K), structured._zero_cot(q4),
            jnp.zeros_like(s), da, db)


lora_linear_kernel_p4.defvjp(_fwd_p4, _bwd_p4)


def lora_supported(x, w0) -> bool:
    if quant.is_packed(w0):
        w0 = w0["q4"]
    elif quant.is_quantized(w0):
        w0 = w0["q"]
    return x.ndim >= 2 and w0.ndim == 2


def lora_linear(x, w0, a, b, bias=None, scale: float = 2.0, *,
                policy=None, interpret=None):
    """Dispatch: Pallas LoRA linear, structured fallback on unsupported
    shapes (MoE per-expert [E,·,·] weights route to
    :func:`lora_grouped_linear` instead). ``w0`` may be a dense matrix, an
    int8 ``{"q", "scale"}`` leaf or a packed 4-bit ``{"q4", "scale"}`` leaf —
    quantized weights route to the dequant-in-VMEM kernels, falling back to
    the structured jnp path on a dequantized copy
    (``core/quant.maybe_dequant``). ``policy`` (ExecutionPolicy) supplies
    kernel overrides (interpret)."""
    if not lora_supported(x, w0):
        return structured.lora_linear(x, quant.maybe_dequant(w0, x.dtype),
                                      a, b, bias, scale)
    interpret = _resolve_interpret(policy, interpret)
    if quant.is_packed(w0):
        y = lora_linear_kernel_p4(x, w0["q4"], w0["scale"], a, b, scale,
                                  interpret, quant.packed_method(w0))
    elif quant.is_quantized(w0):
        y = lora_linear_kernel_q(x, w0["q"], w0["scale"], a, b, scale,
                                 interpret)
    else:
        y = lora_linear_kernel(x, w0, a, b, scale, interpret)
    # bias is frozen (no grad needed): a plain add stores no residuals
    return y + bias if bias is not None else y


# ---------------------------------------------------------------------------
# Grouped LoRA linear: many (W0, A, B) stack entries, one kernel launch.
# Rows are packed so every bm-row tile belongs to one group and an int32
# gid[t] array (scalar-prefetched — values may be runtime-traced) routes each
# tile's stack entries into VMEM. Closes the last structured-jnp fallback in
# pallas mode (MoE per-expert [E,·,·] linears, bf16 AND int8) and powers the
# multi-tenant serving decode path (shared base, per-request adapters).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _grouped_core(x, w0, a, b, gid, scale: float, bm: int,
                  interpret: bool = False):
    """Packed-rows grouped LoRA linear. x:[Mp,K] (Mp % bm == 0, every bm-row
    tile one group), w0:[Ew,K,N] (Ew ∈ {1, E}), a:[E,K,r], b:[E,r,N],
    gid:int32[Mp//bm] -> [Mp,N]."""
    blk = autotune.choose_blocks("lora_grouped", x.dtype, M=x.shape[0],
                                 K=x.shape[1], N=w0.shape[2])
    return _lg.lora_grouped(x, w0, a, b, gid, scale, bm=bm,
                            interpret=interpret, **blk)


def _grouped_fwd(x, w0, a, b, gid, scale, bm, interpret):
    return _grouped_core(x, w0, a, b, gid, scale, bm, interpret), \
        (x, w0, a, b, gid)


def _grouped_bwd(scale, bm, interpret, res, g):
    x, w0, a, b, gid = res
    g = g.astype(x.dtype)
    M, K = x.shape
    N = w0.shape[2]
    dx = _lg.lora_grouped_dx(g, w0, a, b, gid, scale, bm=bm,
                             interpret=interpret,
                             **autotune.choose_blocks("lora_grouped_dx",
                                                      x.dtype, M=M, K=K, N=N))
    da, db = _lg.lora_grouped_dab(x, g, a, b, gid, scale, bm=bm,
                                  interpret=interpret)
    return dx, jnp.zeros_like(w0), da, db, structured._zero_cot(gid)


_grouped_core.defvjp(_grouped_fwd, _grouped_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _grouped_core_q(x, q, s, a, b, gid, scale: float, bm: int,
                    interpret: bool = False):
    """int8-base variant: q:int8[Ew,K,N], s:f32[Ew,1,N] — the per-group dense
    W0 exists only tile-wise in VMEM, never in HBM."""
    blk = autotune.choose_blocks("lora_grouped_q", x.dtype, M=x.shape[0],
                                 K=x.shape[1], N=q.shape[2])
    return _lg.lora_grouped_q(x, q, s, a, b, gid, scale, bm=bm,
                              interpret=interpret, **blk)


def _grouped_fwd_q(x, q, s, a, b, gid, scale, bm, interpret):
    return _grouped_core_q(x, q, s, a, b, gid, scale, bm, interpret), \
        (x, q, s, a, b, gid)


def _grouped_bwd_q(scale, bm, interpret, res, g):
    x, q, s, a, b, gid = res
    g = g.astype(x.dtype)
    M, K = x.shape
    N = q.shape[2]
    dx = _lg.lora_grouped_dx_q(g, q, s, a, b, gid, scale, bm=bm,
                               interpret=interpret,
                               **autotune.choose_blocks("lora_grouped_dx_q",
                                                        x.dtype, M=M, K=K,
                                                        N=N))
    da, db = _lg.lora_grouped_dab(x, g, a, b, gid, scale, bm=bm,
                                  interpret=interpret)
    return (dx, structured._zero_cot(q), jnp.zeros_like(s), da, db,
            structured._zero_cot(gid))


_grouped_core_q.defvjp(_grouped_fwd_q, _grouped_bwd_q)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _grouped_core_p4(x, q4, s, a, b, gid, scale: float, bm: int,
                     interpret: bool = False, method: str = "int4"):
    """Packed-4-bit-base variant: q4:uint8[Ew,ceil(K/2),N], s:f32[Ew,1,N] —
    only the packed bytes + scale leave HBM; the per-group dense W0 exists
    only tile-wise in VMEM."""
    blk = autotune.choose_blocks("lora_grouped_q4", x.dtype, M=x.shape[0],
                                 K=x.shape[1], N=q4.shape[2])
    return _lg.lora_grouped_q4(x, q4, s, a, b, gid, scale, method=method,
                               bm=bm, interpret=interpret, **blk)


def _grouped_fwd_p4(x, q4, s, a, b, gid, scale, bm, interpret, method):
    return (_grouped_core_p4(x, q4, s, a, b, gid, scale, bm, interpret,
                             method),
            (x, q4, s, a, b, gid))


def _grouped_bwd_p4(scale, bm, interpret, method, res, g):
    x, q4, s, a, b, gid = res
    g = g.astype(x.dtype)
    M, K = x.shape
    N = q4.shape[2]
    dx = _lg.lora_grouped_dx_q4(g, q4, s, a, b, gid, scale, method=method,
                                bm=bm, interpret=interpret,
                                **autotune.choose_blocks(
                                    "lora_grouped_dx_q4", x.dtype, M=M, K=K,
                                    N=N))
    da, db = _lg.lora_grouped_dab(x, g, a, b, gid, scale, bm=bm,
                                  interpret=interpret)
    return (dx, structured._zero_cot(q4), jnp.zeros_like(s), da, db,
            structured._zero_cot(gid))


_grouped_core_p4.defvjp(_grouped_fwd_p4, _grouped_bwd_p4)


def _grouped_bm(rows: int) -> int:
    """Row-tile granularity for a group layout: full 128-row tiles for big
    groups, one 8-row-aligned tile otherwise (8 = f32 sublane minimum —
    per-group padding cost scales with bm, so small groups get small tiles)."""
    return 128 if rows >= 128 else tiling.ceil_to(max(rows, 1), 8)


def _grouped_dispatch(xp, w0, a, b, gid, scale, bm, interpret):
    if quant.is_packed(w0):
        return _grouped_core_p4(xp, w0["q4"], w0["scale"], a, b,
                                jnp.asarray(gid, jnp.int32), scale, bm,
                                interpret, quant.packed_method(w0))
    if quant.is_quantized(w0):
        return _grouped_core_q(xp, w0["q"], w0["scale"], a, b,
                               jnp.asarray(gid, jnp.int32), scale, bm,
                               interpret)
    return _grouped_core(xp, w0, a, b, jnp.asarray(gid, jnp.int32), scale,
                         bm, interpret)


def lora_grouped_linear(x, w0, a, b, scale: float = 2.0, *, policy=None,
                        interpret=None):
    """Batched-uniform grouped LoRA linear (the MoE expert shape):
    x:[E,C,K], w0:[E,K,N] dense or quantized ``{"q","scale"}`` ([E,K,N] int8
    + [E,1,N] scale), a:[E,K,r], b:[E,r,N] -> [E,C,N]. Differentiable in
    (x, a, b); W0 is frozen (zero cotangent)."""
    E, C, K = x.shape
    bm = _grouped_bm(C)
    Cp = tiling.ceil_to(C, bm)
    xp = tiling.pad_dim(x, bm, 1).reshape(E * Cp, K)
    gid = np.repeat(np.arange(E, dtype=np.int32), Cp // bm)
    y = _grouped_dispatch(xp, w0, a, b, gid, scale, bm,
                          _resolve_interpret(policy, interpret))
    return y.reshape(E, Cp, -1)[:, :C]


def lora_grouped_ragged(x, group_sizes, w0, a, b, scale: float = 2.0, *,
                        bm: int = 8, policy=None, interpret=None):
    """Ragged grouped LoRA linear: x:[M,K] is the concatenation of per-group
    row blocks (``group_sizes[g]`` rows each, zero-size groups allowed).
    Packing/unpacking to the bm-tile layout happens here (plain jnp, so
    gradients flow through the pad/slice); the packed core carries the
    custom_vjp."""
    sizes = tuple(int(s) for s in group_sizes)
    if quant.is_packed(w0):
        N = w0["q4"].shape[-1]
    elif quant.is_quantized(w0):
        N = w0["q"].shape[-1]
    else:
        N = w0.shape[-1]
    if sum(sizes) == 0:
        return jnp.zeros((0, N), x.dtype)
    gid, _ = tiling.grouped_schedule(sizes, bm)
    xp = tiling.pack_ragged_rows(x, sizes, bm)
    y = _grouped_dispatch(xp, w0, a, b, gid, scale, bm,
                          _resolve_interpret(policy, interpret))
    return tiling.unpack_ragged_rows(y, sizes, bm)


def lora_grouped_decode(x, w0, a, b, tile_gid, bias=None, scale: float = 2.0,
                        *, bm: int = 8, policy=None, interpret=None):
    """Runtime-routed grouped linear for the serving decode path: a shared
    frozen base (w0:[K,N] dense or quantized) plus a *stack* of resident
    adapters (a:[R,K,r], b:[R,r,N]); ``tile_gid`` int32 [M//bm] holds each
    slot tile's AdapterStore slot and may be a traced array — re-routing
    adapters across steps never recompiles. Non-pallas backends use the
    gather reference (same math, jnp)."""
    M, K = x.shape
    if M % bm:
        raise ValueError(f"decode rows {M} not a multiple of tile {bm}")
    if policy is not None and policy.backend == "pallas":
        w0e = (quant.add_group_axis(w0)
               if quant.is_packed(w0) or quant.is_quantized(w0)
               else w0[None])
        y = _grouped_dispatch(x, w0e, a, b, tile_gid, scale, bm,
                              _resolve_interpret(policy, interpret))
    else:
        row_gid = jnp.repeat(jnp.asarray(tile_gid, jnp.int32), bm)
        w = quant.maybe_dequant(w0, x.dtype)
        h = jnp.einsum("mk,mkr->mr", x, a[row_gid])
        y = (x @ w + scale * jnp.einsum("mr,mrn->mn", h, b[row_gid])
             ).astype(x.dtype)
    return y + bias if bias is not None else y


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm_kernel(x, w, eps: float = 1e-6, interpret: bool = False):
    x2 = _flat(x)
    blk = autotune.choose_blocks("rmsnorm", x.dtype, M=x2.shape[0],
                                 d=x2.shape[1])
    return _rn.rmsnorm(x2, w, eps, interpret=interpret,
                       **blk).reshape(x.shape)


def _rn_fwd(x, w, eps, interpret):
    return rmsnorm_kernel(x, w, eps, interpret), (x, w)


def _rn_bwd(eps, interpret, res, g):
    x, w = res
    x2 = _flat(x)
    blk = autotune.choose_blocks("rmsnorm", x.dtype, M=x2.shape[0],
                                 d=x2.shape[1])
    dx, dw = _rn.rmsnorm_bwd(x2, w, _flat(g), eps, interpret=interpret,
                             **blk)
    return dx.reshape(x.shape), dw


rmsnorm_kernel.defvjp(_rn_fwd, _rn_bwd)


def rmsnorm(x, w, eps: float = 1e-6, *, policy=None, interpret=None):
    """Dispatch: fused RMSNorm kernel (any row count — rows padded)."""
    return rmsnorm_kernel(x, w, eps, _resolve_interpret(policy, interpret))


# ---------------------------------------------------------------------------
# Flash attention: Pallas fwd saving per-row logsumexp + Pallas bwd that
# recomputes probabilities tile-wise from it. GQA grouped via index maps;
# causal/window grids are sparse (dead tiles never launched — see
# kernels/flash_attention.py); optional fused RoPE rotates q/k in VMEM.
# ---------------------------------------------------------------------------


def _attn_blocks(Nq, Nk, D, dtype, causal, window):
    # causal/window key the measured cache: the sparse schedule (and so the
    # best block shape) depends on the mask structure
    return autotune.choose_blocks("flash", dtype, Nq=Nq, Nk=Nk, D=D,
                                  causal=int(causal), window=window)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: bool = False, rope=None):
    """q: [B,H,N,D]; k/v: [B,Hkv,Nk,D] -> [B,H,N,D]. Differentiable.
    ``rope=(cos, sin)`` ([N, D/2] f32) fuses the q/k rotation into the
    kernels (tables are treated as constants — zero cotangent)."""
    out, _ = _flash_fwd_impl(q, k, v, rope, causal, window, interpret)
    return out


def _flash_fwd_impl(q, k, v, rope, causal, window, interpret):
    B, H, Nq, D = q.shape
    Hkv, Nk = k.shape[1], k.shape[2]
    blk = _attn_blocks(Nq, Nk, D, q.dtype, causal, window)
    out, lse = _fa.flash_attention_fwd(
        q.reshape(B * H, Nq, D), k.reshape(B * Hkv, Nk, D),
        v.reshape(B * Hkv, Nk, D), rope, causal=causal, window=window,
        q_per_kv=H // Hkv, interpret=interpret, return_lse=True,
        bq=blk["bq"], bk=blk["bk"])
    return out.reshape(B, H, Nq, D), lse


def _flash_vjp_fwd(q, k, v, causal, window, interpret, rope):
    out, lse = _flash_fwd_impl(q, k, v, rope, causal, window, interpret)
    # MeSP residual contract: (q, k, v, out, lse) — probs never stored
    return out, (q, k, v, rope, out, lse)


def _flash_vjp_bwd(causal, window, interpret, res, g):
    q, k, v, rope, out, lse = res
    B, H, Nq, D = q.shape
    Hkv, Nk = k.shape[1], k.shape[2]
    blk = _attn_blocks(Nq, Nk, D, q.dtype, causal, window)
    dq, dk, dv = _fa.flash_attention_bwd(
        q.reshape(B * H, Nq, D), k.reshape(B * Hkv, Nk, D),
        v.reshape(B * Hkv, Nk, D), out.reshape(B * H, Nq, D), lse,
        g.reshape(B * H, Nq, D), rope, causal=causal, window=window,
        q_per_kv=H // Hkv, interpret=interpret,
        bq=blk["bq"], bk=blk["bk"])
    d_rope = None if rope is None else (jnp.zeros_like(rope[0]),
                                        jnp.zeros_like(rope[1]))
    return (dq.reshape(B, H, Nq, D), dk.reshape(B, Hkv, Nk, D),
            dv.reshape(B, Hkv, Nk, D), d_rope)


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def attention_supported(q, k) -> bool:
    if q.ndim != 4 or k.ndim != 4:
        return False
    H, Hkv = q.shape[1], k.shape[1]
    return Hkv >= 1 and H % Hkv == 0 and q.shape[2] >= PALLAS_ATTN_MIN_SEQ


def sdpa(q, k, v, *, causal: bool = True, window: int = 0, policy=None,
         interpret=None, rope=None):
    """Dispatch: flash kernel attention, structured sdpa fallback for short
    sequences / unsupported layouts. ``rope=(cos, sin)`` arrives *unapplied*
    (layers skip the jnp rotation when fusing): the kernel path rotates q/k
    tiles in VMEM; the fallback applies the same tables via jnp first."""
    if not attention_supported(q, k):
        if rope is not None:
            q = _rope.apply_rope_tables(q, *rope)
            k = _rope.apply_rope_tables(k, *rope)
        return structured.sdpa(q, k, v, window, causal)
    return flash_attention(q, k, v, causal, window,
                           _resolve_interpret(policy, interpret), rope)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """Forward-only kernel entry (benchmarks/tests). q: [B,H,N,D]; k/v:
    [B,Hkv,Nk,D] -> [B,H,N,D]. GQA grouped via kernel index maps — K/V are
    never repeated in HBM."""
    B, H, Nq, D = q.shape
    Hkv, Nk = k.shape[1], k.shape[2]
    out = _fa.flash_attention_fwd(
        q.reshape(B * H, Nq, D), k.reshape(B * Hkv, Nk, D),
        v.reshape(B * Hkv, Nk, D), causal=causal, window=window,
        q_per_kv=H // Hkv, bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, Nq, D)
