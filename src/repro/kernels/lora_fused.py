"""Fused LoRA linear Pallas TPU kernel: ``y = x@W0 + s·(x@A)@B``.

TPU-native extension of the paper's core insight (DESIGN.md §2): MeSP saves
HBM *capacity* by never storing ``h = x@A``; on TPU we also save HBM
*bandwidth* by never letting ``h`` leave VMEM — it exists only as a
``[bm, r]`` f32 scratch tile accumulated alongside the main matmul and is
consumed against ``B`` on the final K step. One kernel, one pass over
``x``/``W0``; ``A``/``B`` tiles are tiny (r ≤ 32).

Grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulators persist across
the contraction. MXU alignment: bm/bn/bk multiples of 128 (r is padded to the
lane width by Mosaic automatically).

The backward fusion (``dx = dh@Aᵀ + g@W0ᵀ``) is ``lora_dx.py``'s kernel; the
``dA``/``dB`` contractions are thin (rank-r) matmuls that XLA already emits
optimally, and ``h`` is *recomputed* there exactly as the paper prescribes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lora_fused_kernel(x_ref, w0_ref, a_ref, b_ref, o_ref, acc_ref, h_ref, *,
                       scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    acc_ref[...] += jax.lax.dot(xb, w0_ref[...],
                                preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[...],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_fused(x, w0, a, b, scale: float = 2.0, *, bm: int = 128,
               bn: int = 128, bk: int = 128, interpret: bool = False):
    """x:[M,K] w0:[K,N] a:[K,r] b:[r,N] -> [M,N]. Dims must tile by bm/bn/bk."""
    M, K = x.shape
    N = w0.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_lora_fused_kernel, scale=scale, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w0
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),    # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),    # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),                # W0 accumulator
            pltpu.VMEM((bm, r), jnp.float32),                 # h tile (VMEM!)
        ],
        interpret=interpret,
    )(x, w0, a, b)


def _lora_dx_kernel(g_ref, w0t_ref, dh_ref, at_ref, o_ref, acc_ref, *,
                    n_n: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(g_ref[...], w0t_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot(dh_ref[...], at_ref[...],
                                preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "bn",
                                             "interpret"))
def lora_dx(g, w0, a, b, scale: float = 2.0, *, bm: int = 128, bk: int = 128,
            bn: int = 128, interpret: bool = False):
    """dx = (s·g)@Bᵀ@Aᵀ + g@W0ᵀ  (A.1 eq 13).  g:[M,N] -> dx:[M,K].

    The rank-r intermediate ``dh = s·g@Bᵀ`` is a thin matmul computed here
    (jnp — XLA emits it well); the kernel fuses the two large matmuls so ``g``
    is read once.
    """
    M, N = g.shape
    K = w0.shape[0]
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    dh = ((scale * g) @ b.T).astype(g.dtype)        # [M, r] — tiny
    w0t = w0.T                                      # [N, K]
    at = a.T                                        # [r, K]
    r = at.shape[0]
    n_n = N // bn

    grid = (M // bm, K // bk, n_n)
    return pl.pallas_call(
        functools.partial(_lora_dx_kernel, n_n=n_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),   # g
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, j)),   # w0ᵀ
            pl.BlockSpec((bm, r), lambda i, j, n: (i, 0)),    # dh
            pl.BlockSpec((r, bk), lambda i, j, n: (0, j)),    # aᵀ
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g, w0t, dh, at)
