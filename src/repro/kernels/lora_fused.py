"""Fused LoRA linear Pallas TPU kernels: ``y = x@W0 + s·(x@A)@B``.

TPU-native extension of the paper's core insight (DESIGN.md §2): MeSP saves
HBM *capacity* by never storing ``h = x@A``; on TPU we also save HBM
*bandwidth* by never letting ``h`` leave VMEM — it exists only as a
``[bm, r]`` f32 scratch tile accumulated alongside the main matmul and is
consumed against ``B`` on the final K step. One kernel, one pass over
``x``/``W0``; ``A``/``B`` tiles are tiny (r ≤ 32).

Backward is split the way the paper's A.1 equations factor:

* ``lora_dx``  — dx = dh@Aᵀ + g@W0ᵀ fused so ``g`` is read once.
* ``lora_dab`` — dA = xᵀ(s·g@Bᵀ), dB = hᵀ(s·g) with ``h`` *recomputed*
  tile-wise in VMEM (paper §4.1) and both outputs produced in a single pass
  over ``x``/``g`` (previously three separate jnp matmuls re-reading both
  operands from HBM).

All wrappers zero-pad non-block-aligned dims (see ``tiling.py``) so
arbitrary ``batch×seq`` / feature sizes work; zero rows/cols contribute
nothing to the sliced-back results.

``pl.pallas_call`` closures are built through ``functools.lru_cache``
builders keyed on the static signature, so repeated non-jit calls
(benchmarks, tests, retraces under fresh outer jits) reuse the constructed
call object instead of rebuilding grid/BlockSpecs every time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import block_for, pad_dim


def _lora_fused_kernel(x_ref, w0_ref, a_ref, b_ref, o_ref, acc_ref, h_ref, *,
                       scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    acc_ref[...] += jax.lax.dot(xb, w0_ref[...],
                                preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[...],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _lora_fused_call(Mp: int, Kp: int, Np: int, r: int, dtype_name: str,
                     scale: float, bm: int, bn: int, bk: int,
                     interpret: bool):
    n_k = Kp // bk
    return pl.pallas_call(
        functools.partial(_lora_fused_kernel, scale=scale, n_k=n_k),
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w0
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),    # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),    # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.dtype(dtype_name)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),                # W0 accumulator
            pltpu.VMEM((bm, r), jnp.float32),                 # h tile (VMEM!)
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_fused(x, w0, a, b, scale: float = 2.0, *, bm: int = 128,
               bn: int = 128, bk: int = 128, interpret: bool = False):
    """x:[M,K] w0:[K,N] a:[K,r] b:[r,N] -> [M,N]. Any M/N/K (padded)."""
    M, K = x.shape
    N = w0.shape[1]
    r = a.shape[1]
    bm, bn, bk = block_for(M, bm), block_for(N, bn), block_for(K, bk)
    xp = pad_dim(pad_dim(x, bm, 0), bk, 1)
    w0p = pad_dim(pad_dim(w0, bk, 0), bn, 1)
    ap = pad_dim(a, bk, 0)
    bp = pad_dim(b, bn, 1)
    Mp, Kp = xp.shape
    Np = w0p.shape[1]
    out = _lora_fused_call(Mp, Kp, Np, r, jnp.dtype(x.dtype).name,
                           float(scale), bm, bn, bk,
                           interpret)(xp, w0p, ap, bp)
    return out[:M, :N]


def _lora_dx_kernel(g_ref, w0t_ref, dh_ref, at_ref, o_ref, acc_ref, *,
                    n_n: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(g_ref[...], w0t_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot(dh_ref[...], at_ref[...],
                                preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _lora_dx_call(Mp: int, Kp: int, Np: int, r: int, dtype_name: str,
                  bm: int, bk: int, bn: int, interpret: bool):
    n_n = Np // bn
    return pl.pallas_call(
        functools.partial(_lora_dx_kernel, n_n=n_n),
        grid=(Mp // bm, Kp // bk, n_n),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),   # g
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, j)),   # w0ᵀ
            pl.BlockSpec((bm, r), lambda i, j, n: (i, 0)),    # dh
            pl.BlockSpec((r, bk), lambda i, j, n: (0, j)),    # aᵀ
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Kp), jnp.dtype(dtype_name)),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "bn",
                                             "interpret"))
def lora_dx(g, w0, a, b, scale: float = 2.0, *, bm: int = 128, bk: int = 128,
            bn: int = 128, interpret: bool = False):
    """dx = (s·g)@Bᵀ@Aᵀ + g@W0ᵀ  (A.1 eq 13).  g:[M,N] -> dx:[M,K].

    The rank-r intermediate ``dh = s·g@Bᵀ`` is a thin matmul computed here
    (jnp — XLA emits it well); the kernel fuses the two large matmuls so ``g``
    is read once.
    """
    M, N = g.shape
    K = w0.shape[0]
    bm, bk, bn = block_for(M, bm), block_for(K, bk), block_for(N, bn)
    dh = ((scale * g) @ b.T).astype(g.dtype)        # [M, r] — tiny
    gp = pad_dim(pad_dim(g, bm, 0), bn, 1)
    w0tp = pad_dim(pad_dim(w0.T, bn, 0), bk, 1)     # [Np, Kp]
    dhp = pad_dim(dh, bm, 0)
    atp = pad_dim(a.T, bk, 1)                       # [r, Kp]
    Mp, Np = gp.shape
    Kp = w0tp.shape[1]
    r = atp.shape[0]
    out = _lora_dx_call(Mp, Kp, Np, r, jnp.dtype(g.dtype).name, bm, bk, bn,
                        interpret)(gp, w0tp, dhp, atp)
    return out[:M, :K]


# ---------------------------------------------------------------------------
# fused dA/dB: one pass over x and g, h recomputed tile-wise in VMEM
# ---------------------------------------------------------------------------


def _lora_dab_kernel(x_ref, g_ref, a_ref, b_ref, da_ref, db_ref, *,
                     scale: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...]
    sg = (scale * g_ref[...].astype(jnp.float32)).astype(g_ref.dtype)
    # h = x@A recomputed for this row tile only (paper §4.1) — never in HBM
    h = jax.lax.dot(x, a_ref[...],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    # dh = s·g @ Bᵀ  (A.1 eq 11): contract N
    dh = jax.lax.dot_general(sg, b_ref[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32
                             ).astype(x.dtype)
    # dA += xᵀ dh  (eq 12);  dB += hᵀ s·g  (eq 10): both contract the row dim
    da_ref[...] += jax.lax.dot_general(x, dh, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    db_ref[...] += jax.lax.dot_general(h, sg, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)


@functools.lru_cache(maxsize=None)
def _lora_dab_call(Mp: int, Kp: int, Np: int, r: int, scale: float, bm: int,
                   interpret: bool):
    return pl.pallas_call(
        functools.partial(_lora_dab_kernel, scale=scale),
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, Kp), lambda i: (i, 0)),         # x
            pl.BlockSpec((bm, Np), lambda i: (i, 0)),         # g
            pl.BlockSpec((Kp, r), lambda i: (0, 0)),          # a
            pl.BlockSpec((r, Np), lambda i: (0, 0)),          # b
        ],
        out_specs=[
            pl.BlockSpec((Kp, r), lambda i: (0, 0)),
            pl.BlockSpec((r, Np), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kp, r), jnp.float32),
            jax.ShapeDtypeStruct((r, Np), jnp.float32),
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "interpret"))
def lora_dab(x, g, a, b, scale: float = 2.0, *, bm: int = 256,
             interpret: bool = False):
    """(dA, dB) in one fused pass.  x:[M,K] g:[M,N] a:[K,r] b:[r,N].

    Grid is row-tiles only; ``x``/``g`` stream through VMEM once while the
    [K,r] / [r,N] outputs stay resident and accumulate in f32 (the output
    blocks are revisited every step, so they live in VMEM for the whole
    sweep). Zero-padded rows/cols contribute zero to both outputs (padded-N
    entries of g meet padded-N cols of b; padded-K cols of x meet padded-K
    rows of a). r stays unpadded — Mosaic lane-pads it like the fwd kernel.
    """
    M, K = x.shape
    N = g.shape[1]
    r = a.shape[1]
    bm = block_for(M, bm)
    xp = pad_dim(pad_dim(x, bm, 0), 128, 1)
    gp = pad_dim(pad_dim(g, bm, 0), 128, 1)
    ap = pad_dim(a, 128, 0)
    bp = pad_dim(b, 128, 1)
    Mp, Kp = xp.shape
    Np = gp.shape[1]

    da, db = _lora_dab_call(Mp, Kp, Np, r, float(scale), bm,
                            interpret)(xp, gp, ap, bp)
    return da[:K].astype(a.dtype), db[:, :N].astype(b.dtype)
