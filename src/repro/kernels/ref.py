"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_fused_ref(x, w0, a, b, scale: float):
    """y = x@W0 + s·(x@A)@B.  x:[M,K] w0:[K,N] a:[K,r] b:[r,N]."""
    return (x @ w0 + scale * ((x @ a) @ b)).astype(x.dtype)


def lora_dx_ref(g, w0, a, b, scale: float):
    """dx = (s·g)@Bᵀ@Aᵀ + g@W0ᵀ (paper A.1 eq 13). g:[M,N]."""
    dh = (scale * g) @ b.T
    return (dh @ a.T + g @ w0.T).astype(g.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return ((xf / rms) * w.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_bwd_ref(x, w, g, eps: float = 1e-6):
    """(dx, dw) — paper A.3 eq 22."""
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    xhat = xf / rms
    dxhat = gf * w.astype(jnp.float32)
    dx = (dxhat - xhat * jnp.mean(dxhat * xhat, -1, keepdims=True)) / rms
    dw = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dw.astype(w.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Dense attention oracle. q:[B,H,Nq,D] k/v:[B,H,Nk,D] (heads equal)."""
    B, H, Nq, D = q.shape
    Nk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D)
    qpos = jnp.arange(Nq)[:, None]
    kpos = jnp.arange(Nk)[None, :]
    ok = jnp.ones((Nq, Nk), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
