"""Packed 4-bit base-weight LoRA Pallas kernels: int4/nf4 W0 unpacked in VMEM.

``core/quant.py`` packs two 4-bit weights per byte along the input dimension
(``q4`` uint8 [ceil(K/2), N] + per-output-channel scale row). These kernels
are the TPU execution path for that format: the packed byte tile and its
scale row are the only W0 bytes that ever leave HBM — half the traffic of
the int8 kernels in ``lora_quant.py``, a quarter of bf16. The dense float W0
exists only tile-by-tile inside VMEM, never as an HBM array.

Per K-tile the VPU unpacks a ``[bk/2, bn]`` byte block into a ``[bk, bn]``
value block in front of the MXU:

* both formats: ``lo = v & 0xF``, ``hi = v >> 4``, interleaved back to input
  order (byte row j holds input rows 2j/2j+1) by a stack+reshape that keeps
  the lane (N) dimension intact;
* ``int4``: two's-complement sign extension ``(nib ^ 8) - 8``;
* ``nf4``: a 16-entry codebook lookup, compiled as a chain of 16 vector
  selects against the static :data:`repro.core.quant.NF4_CODE` constants (no
  codebook operand needs to leave HBM).

The per-output-channel scale stays algebraically hoisted across the K-sum
exactly as in the int8 kernels: applied to the accumulator at the final K
step in the forward, folded onto the incoming gradient in the backward.

One structural difference from ``lora_quant.py``: the dx kernel reads the
*untransposed* packed tile. Transposing ``q4`` in HBM would break the
two-nibbles-per-K-pair byte layout, so instead ``g@W0ᵀ`` contracts the N
axis of both operands via ``dot_general`` (the same idiom as the grouped dx
kernel in ``lora_grouped.py``).

Only the two W0-touching ops need packed variants: the forward and the
``dx`` backward. ``dA``/``dB`` never read W0 (paper A.1 eqs 10/12), so the
fused ``lora_dab`` kernel from ``lora_fused.py`` is reused unchanged.

Wrappers follow the ``tiling.py`` contract: every dim zero-padded to the
block grid and sliced back. Zero *bytes* pad the packed operand; for nf4 a
zero nibble decodes to code[0] = -1, which is still harmless — padded K rows
only ever meet zero-padded x rows / are sliced off dx, and padded N columns
carry a zero scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import NF4_CODE
from repro.kernels.tiling import block_for, pad_dim


def _unpack_tile(packed, method: str, dtype):
    """uint8 [bk/2, bn] byte tile -> [bk, bn] dequantized-value tile (no
    scale — that is hoisted out of the K-sum by the caller)."""
    v = packed.astype(jnp.int32)
    lo, hi = v & 0xF, v >> 4
    # interleave to input order: row 2j <- lo[j], row 2j+1 <- hi[j]. The
    # reshape merges the sublane axes only; the lane (N) axis is untouched.
    nib = jnp.stack([lo, hi], axis=1).reshape(2 * v.shape[0], v.shape[1])
    if method == "int4":
        return ((nib ^ 8) - 8).astype(dtype)
    # nf4: 16-entry codebook gather as a static select chain on the VPU
    w = jnp.full(nib.shape, NF4_CODE[0], dtype)
    for i in range(1, 16):
        w = jnp.where(nib == i, jnp.asarray(NF4_CODE[i], dtype), w)
    return w


def _lora_fused_q4_kernel(x_ref, q4_ref, s_ref, a_ref, b_ref, o_ref,
                          acc_ref, h_ref, *, scale: float, n_k: int,
                          method: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    # nibble unpack on the VPU; the scale half of the dequant is deferred to
    # the final K step (it commutes with the K-sum).
    wb = _unpack_tile(q4_ref[...], method, x_ref.dtype)
    acc_ref[...] += jax.lax.dot(xb, wb, preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[...],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] * s_ref[...] +
                      scale * delta).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _lora_fused_q4_call(Mp: int, Kp: int, Np: int, r: int, dtype_name: str,
                        scale: float, bm: int, bn: int, bk: int,
                        method: str, interpret: bool):
    n_k = Kp // bk
    return pl.pallas_call(
        functools.partial(_lora_fused_q4_kernel, scale=scale, n_k=n_k,
                          method=method),
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),     # x
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),  # q4 bytes
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),      # scale row
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),      # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),      # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.dtype(dtype_name)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),                  # W0 accum
            pltpu.VMEM((bm, r), jnp.float32),                   # h tile
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "method", "bm", "bn",
                                             "bk", "interpret"))
def lora_fused_q4(x, q4, s, a, b, scale: float = 2.0, *,
                  method: str = "int4", bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = False):
    """y = x@dequant(q4)·s + s_lora·(x@A)@B.  x:[M,K] q4:uint8[ceil(K/2),N]
    s:f32[1,N] a:[K,r] b:[r,N] -> [M,N]. Any M/N/K (padded, odd K included:
    the stray pad nibble lands on a zero-padded x row)."""
    M, K = x.shape
    N = q4.shape[1]
    r = a.shape[1]
    bm, bn, bk = block_for(M, bm), block_for(N, bn), block_for(K, bk)
    xp = pad_dim(pad_dim(x, bm, 0), bk, 1)
    q4p = pad_dim(pad_dim(q4, bk // 2, 0), bn, 1)
    sp = pad_dim(s.astype(jnp.float32), bn, 1)
    ap = pad_dim(a, bk, 0)
    bp = pad_dim(b, bn, 1)
    Mp, Kp = xp.shape
    Np = q4p.shape[1]
    out = _lora_fused_q4_call(Mp, Kp, Np, r, jnp.dtype(x.dtype).name,
                              float(scale), bm, bn, bk, method,
                              interpret)(xp, q4p, sp, ap, bp)
    return out[:M, :N]


def _lora_dx_q4_kernel(g_ref, s_ref, q4_ref, dh_ref, at_ref, o_ref, acc_ref,
                       *, n_n: int, method: str):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # g@W0ᵀ = (g·s) @ wᵀ: scale is per-N, i.e. per contraction element, so
    # it folds onto the g tile (VPU) before the unpacked tile hits the MXU.
    # The packed tile stays untransposed ([bk, bn] after unpack); the
    # transpose is expressed as a dot_general contraction over N of both.
    gs = g_ref[...] * s_ref[...].astype(g_ref.dtype)
    wb = _unpack_tile(q4_ref[...], method, g_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        gs, wb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot(dh_ref[...], at_ref[...],
                                preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _lora_dx_q4_call(Mp: int, Kp: int, Np: int, r: int, dtype_name: str,
                     bm: int, bk: int, bn: int, method: str,
                     interpret: bool):
    n_n = Np // bn
    return pl.pallas_call(
        functools.partial(_lora_dx_q4_kernel, n_n=n_n, method=method),
        grid=(Mp // bm, Kp // bk, n_n),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),     # g
            pl.BlockSpec((1, bn), lambda i, j, n: (0, n)),      # scale row
            pl.BlockSpec((bk // 2, bn), lambda i, j, n: (j, n)),  # q4 bytes
            pl.BlockSpec((bm, r), lambda i, j, n: (i, 0)),      # dh
            pl.BlockSpec((r, bk), lambda i, j, n: (0, j)),      # aᵀ
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Kp), jnp.dtype(dtype_name)),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "method", "bm", "bk",
                                             "bn", "interpret"))
def lora_dx_q4(g, q4, s, a, b, scale: float = 2.0, *, method: str = "int4",
               bm: int = 128, bk: int = 128, bn: int = 128,
               interpret: bool = False):
    """dx = (s_lora·g)@Bᵀ@Aᵀ + g@dequant(q4)ᵀ·s  (A.1 eq 13).
    g:[M,N] q4:uint8[ceil(K/2),N] -> dx:[M,K].

    Like ``lora_dx_q``: the thin ``dh = s_lora·g@Bᵀ`` matmul stays in jnp;
    the kernel fuses the two large matmuls so ``g`` is read once. Unlike the
    int8 variant no HBM transpose of the table is taken — the packed byte
    layout pairs adjacent K rows, so the kernel contracts the untransposed
    tile instead (quarter the W0 HBM bytes of the bf16 ``w0.T`` copy)."""
    M, N = g.shape
    r = a.shape[1]
    K = a.shape[0]
    bm, bk, bn = block_for(M, bm), block_for(K, bk), block_for(N, bn)
    dh = ((scale * g) @ b.T).astype(g.dtype)        # [M, r] — tiny
    gp = pad_dim(pad_dim(g, bm, 0), bn, 1)
    q4p = pad_dim(pad_dim(q4, bk // 2, 0), bn, 1)   # untransposed bytes
    sp = pad_dim(s.astype(jnp.float32), bn, 1)      # [1, Np]
    dhp = pad_dim(dh, bm, 0)
    atp = pad_dim(a.T, bk, 1)                       # [r, Kp]
    Mp, Np = gp.shape
    Kp = 2 * q4p.shape[0]
    out = _lora_dx_q4_call(Mp, Kp, Np, r, jnp.dtype(g.dtype).name, bm, bk,
                           bn, method, interpret)(gp, sp, q4p, dhp, atp)
    return out[:M, :K]
