"""Fused rotary-embedding Pallas kernel + the cos/sin table helpers.

Two ways RoPE runs on the kernel path:

* **Fused into flash attention** (the production path): ``models/layers.py``
  passes ``rope=(cos, sin)`` tables through ``kernels.ops.sdpa`` and the
  flash kernels rotate the q/k tiles in VMEM right after load
  (``flash_attention._rot``) — the rotated q/k never round-trip through
  HBM, and the backward counter-rotates dq/dk before the final write.
  Traffic drops from 2·[B·H, N, D] extra HBM writes+reads to one
  [N, D/2]·2 table read per tile sweep.
* **Standalone kernel** (this module): ``rope_apply`` is a drop-in for the
  jnp rotation in ``models/layers.rope`` — one pass over x with the angle
  tables streamed per row tile; the backward is the same kernel run with
  ``-sin`` (rotations are orthogonal: dx = R₋θ(dy)), so nothing but the
  tiny tables is saved as residuals.

Tables are position-indexed: ``rope_tables(positions, theta, d)`` matches
``models/layers.rope``'s frequency convention exactly (``theta ** (-i/half)``),
and ``apply_rope_tables`` is the jnp reference used by dispatch fallbacks
and the equivalence tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import block_for, pad_dim


def rope_tables(positions, theta: float, d: int):
    """(cos, sin) f32 tables [N, d//2] for 1-D ``positions`` [N]."""
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope_tables(x, cos, sin):
    """jnp reference rotation: x [..., N, D], tables [N, D//2] (f32).

    Same math as ``models/layers.rope`` (f32 compute, cast back): used by
    the dispatch fallback when the flash kernel path is not taken and as
    the oracle for the fused/standalone kernels.
    """
    half = x.shape[-1] // 2
    shape = (1,) * (x.ndim - 2) + cos.shape
    c, s = cos.reshape(shape), sin.reshape(shape)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# standalone kernel: x [B, N, H, D] (the models/layers.rope layout)
# ---------------------------------------------------------------------------


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[0]                                   # [bn, H, D]
    half = x.shape[-1] // 2
    c = cos_ref[...][:, None, :]                   # [bn, 1, half]
    s = sin_ref[...][:, None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    o_ref[0] = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                               -1).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _rope_call(B: int, Np: int, H: int, D: int, dtype_name: str, bn: int,
               interpret: bool):
    return pl.pallas_call(
        _rope_kernel,
        grid=(B, Np // bn),
        in_specs=[
            pl.BlockSpec((1, bn, H, D), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((bn, D // 2), lambda b, i: (i, 0)),
            pl.BlockSpec((bn, D // 2), lambda b, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, H, D), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Np, H, D), jnp.dtype(dtype_name)),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def rope_fwd(x, cos, sin, *, bn: int = 256, interpret: bool = False):
    """Fused rotation kernel. x: [B, N, H, D]; tables [N, D//2] f32."""
    B, N, H, D = x.shape
    assert cos.shape == (N, D // 2), (cos.shape, x.shape)
    bn = block_for(N, bn)
    xp = pad_dim(x, bn, 1)
    cosp = pad_dim(cos.astype(jnp.float32), bn, 0)
    sinp = pad_dim(sin.astype(jnp.float32), bn, 0)
    call = _rope_call(B, xp.shape[1], H, D, jnp.dtype(x.dtype).name, bn,
                      interpret)
    return call(xp, cosp, sinp)[:, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def rope_apply(x, cos, sin, interpret: bool = False):
    """Differentiable fused RoPE: drop-in for the jnp rotation with the
    backward run as the same kernel at −θ (nothing stored but the tables)."""
    return rope_fwd(x, cos, sin, interpret=interpret)


def _rope_vjp_fwd(x, cos, sin, interpret):
    return rope_fwd(x, cos, sin, interpret=interpret), (cos, sin)


def _rope_vjp_bwd(interpret, res, g):
    cos, sin = res
    # R_θᵀ = R₋θ: same kernel, sin negated; tables are constants (zero cot)
    return (rope_fwd(g, cos, -sin, interpret=interpret),
            jnp.zeros_like(cos), jnp.zeros_like(sin))


rope_apply.defvjp(_rope_vjp_fwd, _rope_vjp_bwd)
