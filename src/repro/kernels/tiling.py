"""Shape padding helpers shared by the Pallas kernel wrappers.

The kernels tile their grids in fixed block sizes; real models have
``batch×seq`` and feature dims that are not multiples of those blocks
(e.g. vocab 51865, reduced d_model 160). Every kernel wrapper zero-pads its
operands up to the block grid and slices the result back — zero rows/cols
are constructed so they contribute exactly nothing to the unpadded outputs
(matmuls with zero rows, attention keys masked by a static valid-length).
"""
from __future__ import annotations

import jax.numpy as jnp

# Block-size alignment for single-block (dim < block) cases. Kernel block
# dims land on the MXU/lane axis in at least one operand (e.g. bk is x's
# lane dim but w0's sublane dim), so every block dim is kept a multiple of
# the 128 lane width — the contract the kernels were designed around. The
# interpreter doesn't care; real Mosaic does.
LANE = 128


def ceil_to(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= n."""
    return -(-n // mult) * mult


def block_for(n: int, blk: int, align: int = LANE) -> int:
    """Clamp a requested block size to dim ``n``: full blocks when n >= blk,
    otherwise one aligned block covering the whole (padded) dim."""
    return blk if n >= blk else ceil_to(n, align)


def pad_dim(x, mult: int, axis: int):
    """Zero-pad ``axis`` of x up to a multiple of ``mult``."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
