"""Shape padding helpers shared by the Pallas kernel wrappers.

The kernels tile their grids in fixed block sizes; real models have
``batch×seq`` and feature dims that are not multiples of those blocks
(e.g. vocab 51865, reduced d_model 160). Every kernel wrapper zero-pads its
operands up to the block grid and slices the result back — zero rows/cols
are constructed so they contribute exactly nothing to the unpadded outputs
(matmuls with zero rows, attention keys masked by a static valid-length).
"""
from __future__ import annotations

import functools

import numpy as np

import jax.numpy as jnp

# Block-size alignment for single-block (dim < block) cases. Kernel block
# dims land on the MXU/lane axis in at least one operand (e.g. bk is x's
# lane dim but w0's sublane dim), so every block dim is kept a multiple of
# the 128 lane width — the contract the kernels were designed around. The
# interpreter doesn't care; real Mosaic does.
LANE = 128


def ceil_to(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= n."""
    return -(-n // mult) * mult


def block_for(n: int, blk: int, align: int = LANE) -> int:
    """Clamp a requested block size to dim ``n``: full blocks when n >= blk,
    otherwise one aligned block covering the whole (padded) dim."""
    return blk if n >= blk else ceil_to(n, align)


def pad_dim(x, mult: int, axis: int):
    """Zero-pad ``axis`` of x up to a multiple of ``mult``."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# sparse flash-attention tile schedules
#
# The flash kernels iterate a *flat* grid over exactly the (q_block, k_block)
# tiles that can contain unmasked entries; the mapping flat-step -> tile is a
# trace-time-computed int32 schedule handed to the kernel via scalar prefetch
# (the BlockSpec index maps read it to pick each step's HBM tile). Everything
# here is static Python/numpy: causal/window/valid-length masks are known at
# trace time, so dead tiles are never launched at all, and tiles whose every
# (q, k) pair is valid are flagged *interior* so the kernel skips building
# the positional mask for them.
# ---------------------------------------------------------------------------


def _tile_live(i: int, j: int, *, bq: int, bk: int, causal: bool,
               window: int, nq: int, nk: int) -> bool:
    """Can tile (i, j) contain any unmasked (q_pos, k_pos) pair?"""
    q_lo = i * bq
    k_lo = j * bk
    if q_lo >= nq or k_lo >= nk:
        return False                                  # fully padded tile
    q_hi = min((i + 1) * bq, nq) - 1                  # last valid position
    k_hi = min((j + 1) * bk, nk) - 1
    if causal and q_hi < k_lo:
        return False                                  # strictly above diag
    if window > 0 and q_lo - k_hi >= window:
        return False                                  # behind the window
    return True


def _tile_interior(i: int, j: int, *, bq: int, bk: int, causal: bool,
                   window: int, nq: int, nk: int) -> bool:
    """Is every (q_pos, k_pos) pair of the *full* tile valid (no mask)?"""
    if (i + 1) * bq > nq or (j + 1) * bk > nk:
        return False                                  # touches padding
    q_lo, q_hi = i * bq, (i + 1) * bq - 1
    k_lo, k_hi = j * bk, (j + 1) * bk - 1
    if causal and q_lo < k_hi:
        return False                                  # diagonal crosses tile
    if window > 0 and q_hi - k_lo >= window:
        return False                                  # window edge crosses
    return True


@functools.lru_cache(maxsize=None)
def flash_schedule(n_q: int, n_k: int, bq: int, bk: int, causal: bool,
                   window: int, nq: int, nk: int, sparse: bool = True):
    """Row-major (q-outer) tile schedule for the flash fwd / bwd-dq grids.

    Returns int32 numpy arrays ``(qi, kj, interior)`` of equal length T: step
    t of the flat grid visits tile ``(qi[t], kj[t])``; ``interior[t]`` is 1
    when the kernel may skip mask construction. Tiles of one q row are
    contiguous and ascending in kj, so the kernel detects row start/end by
    comparing ``qi`` at t±1. A q row block with valid rows but *no* live
    tile (fully-masked rows, e.g. causal+window with nq > nk+window) gets
    one boundary dummy tile so its output block is still initialized and
    written (the kernel zeroes never-attended rows). ``sparse=False`` emits
    the dense row-major sweep — the reference grid the tests and benchmarks
    compare against.
    """
    qi, kj, interior = [], [], []
    for i in range(n_q):
        if i * bq >= nq:
            continue                                  # fully padded q rows
        if sparse:
            cols = [j for j in range(n_k)
                    if _tile_live(i, j, bq=bq, bk=bk, causal=causal,
                                  window=window, nq=nq, nk=nk)]
        else:
            cols = list(range(n_k))
        if not cols:
            cols = [0]                                # dummy: init + write
        for j in cols:
            qi.append(i)
            kj.append(j)
            interior.append(int(sparse and _tile_interior(
                i, j, bq=bq, bk=bk, causal=causal, window=window,
                nq=nq, nk=nk)))
    return (np.asarray(qi, np.int32), np.asarray(kj, np.int32),
            np.asarray(interior, np.int32))


@functools.lru_cache(maxsize=None)
def flash_schedule_kv(n_q: int, n_k: int, bq: int, bk: int, causal: bool,
                      window: int, nq: int, nk: int, G: int,
                      sparse: bool = True):
    """Transposed (k-outer) schedule for the bwd-dkv grid.

    Returns ``(kj, g, qi, interior)``: one K/V block stays resident while
    all ``G`` GQA group members' live q rows stream past it. Entries of one
    k column are contiguous (g-major, then ascending qi) so the kernel
    detects column start/end by comparing ``kj`` at t±1. A k column block
    with valid keys but no live q tile (e.g. causal with nk > nq) gets one
    dummy tile so dk/dv are written as zeros there.
    """
    kj, g, qi, interior = [], [], [], []
    for j in range(n_k):
        if j * bk >= nk:
            continue
        rows = [i for i in range(n_q)
                if _tile_live(i, j, bq=bq, bk=bk, causal=causal,
                              window=window, nq=nq, nk=nk)] if sparse \
            else list(range(n_q))
        entries = [(gg, i) for gg in range(G) for i in rows] or [(0, 0)]
        for gg, i in entries:
            kj.append(j)
            g.append(gg)
            qi.append(i)
            interior.append(int(sparse and rows and _tile_interior(
                i, j, bq=bq, bk=bk, causal=causal, window=window,
                nq=nq, nk=nk)))
    return (np.asarray(kj, np.int32), np.asarray(g, np.int32),
            np.asarray(qi, np.int32), np.asarray(interior, np.int32))


# ---------------------------------------------------------------------------
# grouped (ragged per-adapter / per-expert) LoRA tile schedules
#
# The grouped LoRA kernels flatten a set of row groups — MoE expert buffers,
# or per-user adapter micro-batches — into one [Mp, K] operand where every
# bm-row tile belongs to exactly one group. The flat-step -> group mapping is
# an int32 schedule handed to the kernel via scalar prefetch; the BlockSpec
# index maps read ``gid[t]`` to gather that tile's (W0, A, B) stack entry
# into VMEM. Group sizes are static here (trace-time numpy), so empty groups
# launch no tiles at all; the decode path instead passes a *runtime* gid
# array over a fixed slot layout (grid size static, values traced).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def grouped_schedule(group_sizes: tuple, bm: int):
    """Tile schedule for a ragged group layout padded to ``bm`` rows.

    Each group g with ``s = group_sizes[g] > 0`` rows occupies a contiguous
    ``ceil_to(s, bm)``-row span of the packed layout; empty groups occupy
    nothing (no tiles launched — the "live (group, tile) pairs only" contract
    mirrors ``flash_schedule``). Returns ``(gid, offs)``: int32 numpy
    ``gid[t]`` is the group of flat tile t, and ``offs[g]`` the packed row
    offset of group g (``offs[-1] == Mp``). Tiles of one group are contiguous,
    so the dA/dB kernel detects group boundaries by comparing gid at t±1.
    """
    gid, offs = [], [0]
    for g, s in enumerate(group_sizes):
        t = ceil_to(int(s), bm) // bm
        gid.extend([g] * t)
        offs.append(offs[-1] + t * bm)
    return np.asarray(gid, np.int32), np.asarray(offs, np.int64)


def pack_ragged_rows(x, group_sizes: tuple, bm: int):
    """[M, K] concatenated ragged groups -> [Mp, K] with every group's span
    zero-padded to a ``bm`` multiple (so each tile sees one group only)."""
    segs, off = [], 0
    for s in group_sizes:
        s = int(s)
        if s == 0:
            continue
        segs.append(pad_dim(x[off:off + s], bm, 0))
        off += s
    if not segs:
        return jnp.zeros((0,) + x.shape[1:], x.dtype)
    return jnp.concatenate(segs, 0)


def unpack_ragged_rows(xp, group_sizes: tuple, bm: int):
    """Inverse of :func:`pack_ragged_rows`: slice each group's valid rows
    back out of the padded layout and re-concatenate."""
    segs, poff = [], 0
    for s in group_sizes:
        s = int(s)
        if s == 0:
            continue
        segs.append(xp[poff:poff + s])
        poff += ceil_to(s, bm)
    if not segs:
        return jnp.zeros((0,) + xp.shape[1:], xp.dtype)
    return jnp.concatenate(segs, 0)


def grouped_schedule_stats(group_sizes: tuple, bm: int) -> dict:
    """Live-tile counts for a ragged group layout — the arithmetic behind
    the serving benchmark columns. The dense reference is the batched
    ``[E, Cmax, ·]`` layout (every group padded to the largest group), which
    is what a naive per-expert/per-adapter batched matmul would launch."""
    sizes = [int(s) for s in group_sizes]
    gid, offs = grouped_schedule(tuple(sizes), bm)
    cmax = max(sizes) if sizes else 0
    dense = len(sizes) * (ceil_to(cmax, bm) // bm)
    live = int(len(gid))
    return {
        "bm": bm,
        "groups": len(sizes),
        "empty_groups": sum(1 for s in sizes if s == 0),
        "rows": sum(sizes),
        "padded_rows": int(offs[-1]),
        "dense_tiles": dense,
        "live_tiles": live,
        "grid_fraction": live / float(dense) if dense else 1.0,
    }


def flash_schedule_stats(Nq: int, Nk: int, bq: int, bk: int, causal: bool,
                         window: int) -> dict:
    """Live/interior/boundary tile counts for one head's (fwd or bwd-dq)
    grid — the arithmetic behind the benchmark columns. Block sizes are
    clamped the same way the kernel wrappers clamp them."""
    bq, bk = block_for(Nq, bq), block_for(Nk, bk)
    n_q, n_k = ceil_to(Nq, bq) // bq, ceil_to(Nk, bk) // bk
    qi, kj, interior = flash_schedule(n_q, n_k, bq, bk, causal, window,
                                      Nq, Nk, True)
    live = int(len(qi))
    inter = int(interior.sum())
    return {
        "bq": bq, "bk": bk,
        "dense_tiles": n_q * n_k,
        "live_tiles": live,
        "interior_tiles": inter,
        "boundary_tiles": live - inter,
        "grid_fraction": live / float(n_q * n_k),
        # MXU work actually launched: 2 matmuls of 2·bq·bk·D flops each per
        # tile -> report tile count; callers scale by per-tile flops.
    }
