"""Pallas TPU kernels for the paper's compute hot spots.

* ``lora_fused``       — y = x@W0 + s·(x@A)@B with h kept in VMEM (fwd), the
                         fused dx backward, and the one-pass fused dA/dB
                         backward with h recomputed tile-wise (paper A.1).
* ``lora_quant``       — the same fwd/dx with int8 W0 (paper §4.5):
                         q·scale dequantized tile-wise in VMEM, dense W0
                         never materialized in HBM; dA/dB shared with
                         ``lora_fused`` (they don't read W0).
* ``rmsnorm``          — fused forward / structured backward (paper A.3).
* ``flash_attention``  — online-softmax forward emitting per-row logsumexp +
                         a backward that recomputes probabilities from it
                         (paper §2's recompute-over-store principle). GQA is
                         grouped via kernel index maps — K/V never repeated.
                         Grids are *sparse*: causal/window/padding dead
                         tiles are dropped at trace time via scalar-prefetch
                         schedules (``tiling.flash_schedule``); optional
                         fused RoPE rotates q/k tiles in VMEM.
* ``rope``             — cos/sin table helpers + the standalone fused RoPE
                         kernel (backward = same kernel at −θ).
* ``ops``              — the dispatch layer behind the ``pallas``
  ExecutionPolicy backend: per-op
                         structured-jnp fallback on unsupported shapes,
                         interpret mode off-TPU, block sizes from
                         ``autotune`` (heuristic table + measured cache).
* ``tiling``           — zero-pad/slice wrappers so arbitrary batch×seq and
                         feature dims work (no divisibility requirements).

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``; tests sweep shapes/dtypes in interpret mode against the oracles
and against the structured custom_vjp rules.
"""
from repro.kernels import (autotune, lora_quant, ops, ref, rope,  # noqa: F401
                           tiling)
