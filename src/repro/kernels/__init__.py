"""Pallas TPU kernels for the paper's compute hot spots.

* ``lora_fused``       — y = x@W0 + s·(x@A)@B with h kept in VMEM (fwd) and
                         the fused dx backward (paper A.1).
* ``rmsnorm``          — fused forward / structured backward (paper A.3).
* ``flash_attention``  — online-softmax forward (paper §2's recompute-over-
                         store principle applied to attention).

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``; tests sweep shapes/dtypes in interpret mode against the oracles.
"""
from repro.kernels import ops, ref  # noqa: F401
