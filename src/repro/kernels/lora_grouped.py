"""Grouped/ragged LoRA Pallas kernels: per-tile adapter gather via scalar
prefetch.

``lora_fused.py`` binds ONE (W0, A, B) triple per call. Two workloads need
many: MoE per-expert linears (``[E, ·, ·]`` weight stacks, until now a
structured-jnp fallback in pallas mode) and multi-tenant serving, where each
request in a decode batch owns a private user adapter. This family runs

    y[m] = x[m] @ W0[g(m)] + s · (x[m] @ A[g(m)]) @ B[g(m)]

in one kernel launch over all groups: rows are packed so every ``bm``-row
tile belongs to exactly one group, and an int32 ``gid[t]`` array — handed to
the kernel through ``pltpu.PrefetchScalarGridSpec``, the same idiom as the
flash kernels' tile schedules — is read by the BlockSpec index maps to
gather tile t's stack entries into VMEM. The grid size is static but the
``gid`` *values* may be runtime-traced, so the serving decode path re-routes
adapters across steps with zero recompiles.

Two W0 layouts, chosen statically by ``Ew = w0.shape[0]``:

* ``Ew == E`` — per-group base (MoE experts): W0 tile indexed by ``gid[t]``.
* ``Ew == 1`` — shared base (serving: one frozen model, many adapters):
  every tile reads stack entry 0; only A/B are per-group.

Quantized variants mirror ``lora_quant.py``/``lora_pack4.py``: the per-group
int8 tile is cast — or the packed int4/nf4 byte tile nibble-unpacked — to
the activation dtype on the VPU, and the per-output-channel scale row is
applied once per output tile (on the accumulator in the forward, folded onto
``g`` in ``dx``) — a dense per-expert W0 never exists in HBM. The packed
stack is ``[Ew, ceil(K/2), N]`` uint8: multi-tenant serving and pallas-mode
MoE experts get the same 4× W0 residency cut as single-base training.

``lora_grouped_dab`` accumulates dA/dB *per group*: its output BlockSpecs
are indexed by ``gid[t]``, so it requires the tiles of each group to be
contiguous in the schedule (the ``tiling.grouped_schedule`` contract —
group-first detection compares gid at t±1, exactly like the flash kernels'
row-boundary detection). Groups that own no tiles are zeroed by a live-group
mask after the call.

Wrappers pad K/N to the block grid per ``tiling.py``; rows arrive already
packed to ``bm`` multiples by the dispatch layer (``ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lora_pack4 import _unpack_tile
from repro.kernels.tiling import block_for, pad_dim


def _w_index(Ew: int):
    """Index-map factory for the W0/q/scale stacks: per-group entry when the
    stack is [E,·,·], entry 0 always when the base is shared ([1,·,·])."""
    if Ew == 1:
        return lambda t, gid: 0
    return lambda t, gid: gid[t]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _grouped_fwd_kernel(gid_ref, x_ref, w_ref, a_ref, b_ref, o_ref,
                        acc_ref, h_ref, *, scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    acc_ref[...] += jax.lax.dot(xb, w_ref[0],
                                preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[0],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[0],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


def _grouped_fwd_q_kernel(gid_ref, x_ref, q_ref, s_ref, a_ref, b_ref, o_ref,
                          acc_ref, h_ref, *, scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    wb = q_ref[0].astype(x_ref.dtype)                 # dequant-in-VMEM
    acc_ref[...] += jax.lax.dot(xb, wb, preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[0],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[0],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] * s_ref[0] +
                      scale * delta).astype(o_ref.dtype)


def _grouped_fwd_q4_kernel(gid_ref, x_ref, q4_ref, s_ref, a_ref, b_ref,
                           o_ref, acc_ref, h_ref, *, scale: float, n_k: int,
                           method: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[...]
    wb = _unpack_tile(q4_ref[0], method, x_ref.dtype)  # nibble unpack (VPU)
    acc_ref[...] += jax.lax.dot(xb, wb, preferred_element_type=jnp.float32)
    h_ref[...] += jax.lax.dot(xb, a_ref[0],
                              preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        delta = jax.lax.dot(h_ref[...].astype(x_ref.dtype), b_ref[0],
                            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] * s_ref[0] +
                      scale * delta).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _grouped_fwd_call(Mp: int, Kp: int, Np: int, Ew: int, E: int, r: int,
                      dtype_name: str, scale: float, bm: int, bn: int,
                      bk: int, interpret: bool, quant: str):
    n_k = Kp // bk
    wi = _w_index(Ew)
    packed = quant in ("int4", "nf4")
    wblk = (1, bk // 2, bn) if packed else (1, bk, bn)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda t, j, k, gid: (t, k)),          # x
        pl.BlockSpec(wblk, lambda t, j, k, gid: (wi(t, gid), k, j)),
    ]
    if quant != "none":
        in_specs.append(
            pl.BlockSpec((1, 1, bn), lambda t, j, k, gid: (wi(t, gid), 0, j)))
    in_specs += [
        pl.BlockSpec((1, bk, r), lambda t, j, k, gid: (gid[t], k, 0)),  # a
        pl.BlockSpec((1, r, bn), lambda t, j, k, gid: (gid[t], 0, j)),  # b
    ]
    if packed:
        kern = functools.partial(_grouped_fwd_q4_kernel, method=quant)
    elif quant == "int8":
        kern = _grouped_fwd_q_kernel
    else:
        kern = _grouped_fwd_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda t, j, k, gid: (t, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),        # W0 accumulator
            pltpu.VMEM((bm, r), jnp.float32),         # h tile (VMEM only)
        ],
    )
    return pl.pallas_call(
        functools.partial(kern, scale=scale, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.dtype(dtype_name)),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_grouped(x, w0, a, b, gid, scale: float = 2.0, *, bm: int = 128,
                 bn: int = 128, bk: int = 128, interpret: bool = False):
    """x:[Mp,K] (rows packed to bm-tiles of one group each) w0:[Ew,K,N]
    a:[E,K,r] b:[E,r,N] gid:int32[Mp//bm] -> [Mp,N]."""
    Mp, K = x.shape
    Ew, _, N = w0.shape
    E, _, r = a.shape
    bn, bk = block_for(N, bn), block_for(K, bk)
    xp = pad_dim(x, bk, 1)
    w0p = pad_dim(pad_dim(w0, bk, 1), bn, 2)
    ap = pad_dim(a, bk, 1)
    bp = pad_dim(b, bn, 2)
    Kp, Np = xp.shape[1], w0p.shape[2]
    out = _grouped_fwd_call(Mp, Kp, Np, Ew, E, r, jnp.dtype(x.dtype).name,
                            float(scale), bm, bn, bk, interpret,
                            "none")(jnp.asarray(gid, jnp.int32),
                                    xp, w0p, ap, bp)
    return out[:, :N]


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_grouped_q(x, q, s, a, b, gid, scale: float = 2.0, *, bm: int = 128,
                   bn: int = 128, bk: int = 128, interpret: bool = False):
    """Quantized-base grouped forward. q:int8[Ew,K,N] s:f32[Ew,1,N]."""
    Mp, K = x.shape
    Ew, _, N = q.shape
    E, _, r = a.shape
    bn, bk = block_for(N, bn), block_for(K, bk)
    xp = pad_dim(x, bk, 1)
    qp = pad_dim(pad_dim(q, bk, 1), bn, 2)
    sp = pad_dim(s.astype(jnp.float32), bn, 2)
    ap = pad_dim(a, bk, 1)
    bp = pad_dim(b, bn, 2)
    Kp, Np = xp.shape[1], qp.shape[2]
    out = _grouped_fwd_call(Mp, Kp, Np, Ew, E, r, jnp.dtype(x.dtype).name,
                            float(scale), bm, bn, bk, interpret,
                            "int8")(jnp.asarray(gid, jnp.int32),
                                    xp, qp, sp, ap, bp)
    return out[:, :N]


@functools.partial(jax.jit, static_argnames=("scale", "method", "bm", "bn",
                                             "bk", "interpret"))
def lora_grouped_q4(x, q4, s, a, b, gid, scale: float = 2.0, *,
                    method: str = "int4", bm: int = 128, bn: int = 128,
                    bk: int = 128, interpret: bool = False):
    """Packed-4-bit-base grouped forward. q4:uint8[Ew,ceil(K/2),N]
    s:f32[Ew,1,N]; K is taken from x (odd K: pad nibble meets zero x)."""
    Mp, K = x.shape
    Ew, _, N = q4.shape
    E, _, r = a.shape
    bn, bk = block_for(N, bn), block_for(K, bk)
    xp = pad_dim(x, bk, 1)
    qp = pad_dim(pad_dim(q4, bk // 2, 1), bn, 2)
    sp = pad_dim(s.astype(jnp.float32), bn, 2)
    ap = pad_dim(a, bk, 1)
    bp = pad_dim(b, bn, 2)
    Kp, Np = xp.shape[1], qp.shape[2]
    out = _grouped_fwd_call(Mp, Kp, Np, Ew, E, r, jnp.dtype(x.dtype).name,
                            float(scale), bm, bn, bk, interpret,
                            method)(jnp.asarray(gid, jnp.int32),
                                    xp, qp, sp, ap, bp)
    return out[:, :N]


# ---------------------------------------------------------------------------
# dx backward
# ---------------------------------------------------------------------------


def _grouped_dx_kernel(gid_ref, g_ref, w_ref, dh_ref, a_ref, o_ref, acc_ref,
                       *, n_n: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # g @ W0[g]ᵀ: contract the shared N dim of the untransposed stack entry
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...], w_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot_general(
            dh_ref[...], a_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


def _grouped_dx_q_kernel(gid_ref, g_ref, q_ref, s_ref, dh_ref, a_ref, o_ref,
                         acc_ref, *, n_n: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # g@(q·s)ᵀ = (g·s) @ qᵀ: fold the per-N scale onto g before the MXU
    gs = g_ref[...] * s_ref[0].astype(g_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        gs, q_ref[0].astype(g_ref.dtype), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot_general(
            dh_ref[...], a_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


def _grouped_dx_q4_kernel(gid_ref, g_ref, q4_ref, s_ref, dh_ref, a_ref,
                          o_ref, acc_ref, *, n_n: int, method: str):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # g@(dequant(q4)·s)ᵀ = (g·s) @ wᵀ: fold the per-N scale onto g, unpack
    # the untransposed byte tile, contract the shared N dim of both
    gs = g_ref[...] * s_ref[0].astype(g_ref.dtype)
    wb = _unpack_tile(q4_ref[0], method, g_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        gs, wb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n == n_n - 1)
    def _finish():
        lora_part = jax.lax.dot_general(
            dh_ref[...], a_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora_part).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _grouped_dx_call(Mp: int, Kp: int, Np: int, Ew: int, E: int, r: int,
                     dtype_name: str, bm: int, bk: int, bn: int,
                     interpret: bool, quant: str):
    n_n = Np // bn
    wi = _w_index(Ew)
    packed = quant in ("int4", "nf4")
    wblk = (1, bk // 2, bn) if packed else (1, bk, bn)
    in_specs = [
        pl.BlockSpec((bm, bn), lambda t, j, n, gid: (t, n)),          # g
        pl.BlockSpec(wblk, lambda t, j, n, gid: (wi(t, gid), j, n)),
    ]
    if quant != "none":
        in_specs.append(
            pl.BlockSpec((1, 1, bn), lambda t, j, n, gid: (wi(t, gid), 0, n)))
    in_specs += [
        pl.BlockSpec((bm, r), lambda t, j, n, gid: (t, 0)),           # dh
        pl.BlockSpec((1, bk, r), lambda t, j, n, gid: (gid[t], j, 0)),  # a
    ]
    if packed:
        kern = functools.partial(_grouped_dx_q4_kernel, method=quant)
    elif quant == "int8":
        kern = _grouped_dx_q_kernel
    else:
        kern = _grouped_dx_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Mp // bm, Kp // bk, n_n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bk), lambda t, j, n, gid: (t, j)),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(kern, n_n=n_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, Kp), jnp.dtype(dtype_name)),
        interpret=interpret,
    )


def _grouped_dh(g, b, gid, scale: float, bm: int):
    """dh = s·g @ B[g]ᵀ per row — thin [Mp, r], gathered per tile (jnp; the
    gather is r·N bytes per tile, XLA emits it well)."""
    Mp, N = g.shape
    T = Mp // bm
    gt = (scale * g).reshape(T, bm, N)
    bt = b[jnp.asarray(gid, jnp.int32)]               # [T, r, N]
    return jnp.einsum("tmn,trn->tmr", gt, bt).reshape(Mp, -1).astype(g.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "bn",
                                             "interpret"))
def lora_grouped_dx(g, w0, a, b, gid, scale: float = 2.0, *, bm: int = 128,
                    bk: int = 128, bn: int = 128, interpret: bool = False):
    """dx = (s·g)@B[g]ᵀ@A[g]ᵀ + g@W0[g]ᵀ.  g:[Mp,N] -> dx:[Mp,K]."""
    Mp, N = g.shape
    Ew, K, _ = w0.shape
    E, _, r = a.shape
    bk, bn = block_for(K, bk), block_for(N, bn)
    dh = _grouped_dh(g, b, gid, scale, bm)
    gp = pad_dim(g, bn, 1)
    w0p = pad_dim(pad_dim(w0, bk, 1), bn, 2)
    ap = pad_dim(a, bk, 1)
    Np, Kp = gp.shape[1], w0p.shape[1]
    out = _grouped_dx_call(Mp, Kp, Np, Ew, E, r, jnp.dtype(g.dtype).name,
                           bm, bk, bn, interpret,
                           "none")(jnp.asarray(gid, jnp.int32),
                                   gp, w0p, dh, ap)
    return out[:, :K]


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "bn",
                                             "interpret"))
def lora_grouped_dx_q(g, q, s, a, b, gid, scale: float = 2.0, *,
                      bm: int = 128, bk: int = 128, bn: int = 128,
                      interpret: bool = False):
    """Quantized-base grouped dx. q:int8[Ew,K,N] s:f32[Ew,1,N]."""
    Mp, N = g.shape
    Ew, K, _ = q.shape
    E, _, r = a.shape
    bk, bn = block_for(K, bk), block_for(N, bn)
    dh = _grouped_dh(g, b, gid, scale, bm)
    gp = pad_dim(g, bn, 1)
    qp = pad_dim(pad_dim(q, bk, 1), bn, 2)
    sp = pad_dim(s.astype(jnp.float32), bn, 2)
    ap = pad_dim(a, bk, 1)
    Np, Kp = gp.shape[1], qp.shape[1]
    out = _grouped_dx_call(Mp, Kp, Np, Ew, E, r, jnp.dtype(g.dtype).name,
                           bm, bk, bn, interpret,
                           "int8")(jnp.asarray(gid, jnp.int32),
                                   gp, qp, sp, dh, ap)
    return out[:, :K]


@functools.partial(jax.jit, static_argnames=("scale", "method", "bm", "bk",
                                             "bn", "interpret"))
def lora_grouped_dx_q4(g, q4, s, a, b, gid, scale: float = 2.0, *,
                       method: str = "int4", bm: int = 128, bk: int = 128,
                       bn: int = 128, interpret: bool = False):
    """Packed-4-bit-base grouped dx. q4:uint8[Ew,ceil(K/2),N] s:f32[Ew,1,N].
    K is taken from a ([E,K,r]); dx rows past K are sliced off."""
    Mp, N = g.shape
    Ew = q4.shape[0]
    E, K, r = a.shape
    bk, bn = block_for(K, bk), block_for(N, bn)
    dh = _grouped_dh(g, b, gid, scale, bm)
    gp = pad_dim(g, bn, 1)
    qp = pad_dim(pad_dim(q4, bk // 2, 1), bn, 2)    # untransposed bytes
    sp = pad_dim(s.astype(jnp.float32), bn, 2)
    ap = pad_dim(a, bk, 1)
    Np, Kp = gp.shape[1], 2 * qp.shape[1]
    out = _grouped_dx_call(Mp, Kp, Np, Ew, E, r, jnp.dtype(g.dtype).name,
                           bm, bk, bn, interpret,
                           method)(jnp.asarray(gid, jnp.int32),
                                   gp, qp, sp, dh, ap)
    return out[:, :K]


# ---------------------------------------------------------------------------
# fused per-group dA/dB
# ---------------------------------------------------------------------------


def _grouped_dab_kernel(gid_ref, x_ref, g_ref, a_ref, b_ref, da_ref, db_ref,
                        *, scale: float):
    t = pl.program_id(0)
    # first tile of a contiguous group run -> this (da, db) block is fresh
    first = (t == 0) | (gid_ref[t] != gid_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...]
    sg = (scale * g_ref[...].astype(jnp.float32)).astype(g_ref.dtype)
    # h recomputed for this tile only (paper §4.1) — never in HBM
    h = jax.lax.dot(x, a_ref[0],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    dh = jax.lax.dot_general(sg, b_ref[0], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32
                             ).astype(x.dtype)
    da_ref[...] += jax.lax.dot_general(
        x, dh, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]
    db_ref[...] += jax.lax.dot_general(
        h, sg, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]


@functools.lru_cache(maxsize=None)
def _grouped_dab_call(Mp: int, Kp: int, Np: int, E: int, r: int,
                      scale: float, bm: int, interpret: bool):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, Kp), lambda t, gid: (t, 0)),            # x
            pl.BlockSpec((bm, Np), lambda t, gid: (t, 0)),            # g
            pl.BlockSpec((1, Kp, r), lambda t, gid: (gid[t], 0, 0)),  # a
            pl.BlockSpec((1, r, Np), lambda t, gid: (gid[t], 0, 0)),  # b
        ],
        out_specs=[
            pl.BlockSpec((1, Kp, r), lambda t, gid: (gid[t], 0, 0)),
            pl.BlockSpec((1, r, Np), lambda t, gid: (gid[t], 0, 0)),
        ],
        scratch_shapes=[],
    )
    return pl.pallas_call(
        functools.partial(_grouped_dab_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((E, Kp, r), jnp.float32),
            jax.ShapeDtypeStruct((E, r, Np), jnp.float32),
        ],
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "bm", "interpret"))
def lora_grouped_dab(x, g, a, b, gid, scale: float = 2.0, *, bm: int = 128,
                     interpret: bool = False):
    """(dA, dB) per group, one pass over x/g. x:[Mp,K] g:[Mp,N] a:[E,K,r]
    b:[E,r,N] -> (dA:[E,K,r], dB:[E,r,N]).

    REQUIRES each group's tiles contiguous in ``gid`` (the
    ``grouped_schedule`` contract): a group's output block stays resident in
    VMEM across its run and is flushed when the next group's first tile
    remaps the BlockSpec. Groups owning no tiles are zeroed by the live mask
    (their output blocks were never written — contents undefined).
    """
    Mp, K = x.shape
    N = g.shape[1]
    E, _, r = a.shape
    xp = pad_dim(x, 128, 1)
    gp = pad_dim(g, 128, 1)
    ap = pad_dim(a, 128, 1)
    bp = pad_dim(b, 128, 2)
    Kp, Np = xp.shape[1], gp.shape[1]
    gid = jnp.asarray(gid, jnp.int32)
    da, db = _grouped_dab_call(Mp, Kp, Np, E, r, float(scale), bm,
                               interpret)(gid, xp, gp, ap, bp)
    live = jnp.zeros((E,), bool).at[gid].set(True)
    da = jnp.where(live[:, None, None], da[:, :K], 0.0)
    db = jnp.where(live[:, None, None], db[:, :, :N], 0.0)
    return da.astype(a.dtype), db.astype(b.dtype)
