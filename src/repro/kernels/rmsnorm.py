"""Fused RMSNorm Pallas TPU kernels (forward + backward, paper A.3).

Forward reads ``x`` once (single pass: square-mean, rsqrt, scale — no
separate mean kernel); backward recomputes rms/xhat from the saved ``x``
(the MeSP residual contract: residual = x only) and emits dx plus a
per-row-block partial dw that the wrapper sum-reduces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import block_for, pad_dim


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    o_ref[...] = (x * rms * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm(x, w, eps: float = 1e-6, *, bm: int = 256,
            interpret: bool = False):
    """x: [M, d]; w: [d]. Row-block grid (any M — rows padded); d stays
    whole in VMEM. Padded rows normalize zeros (rsqrt(eps)) and are sliced."""
    M, d = x.shape
    bm = block_for(M, bm)
    xp = pad_dim(x, bm, 0)
    Mp = xp.shape[0]
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, d), x.dtype),
        interpret=interpret,
    )(xp, w.reshape(1, d))
    return out[:M]


def _rmsnorm_bwd_kernel(x_ref, w_ref, g_ref, dx_ref, dwp_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    xhat = x * rms
    dxhat = g * w
    dx = (dxhat - xhat * jnp.mean(dxhat * xhat, -1, keepdims=True)) * rms
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dwp_ref[...] = jnp.sum(g * xhat, 0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm_bwd(x, w, g, eps: float = 1e-6, *, bm: int = 256,
                interpret: bool = False):
    """Returns (dx, dw). Per-block dw partials reduced by the wrapper.
    Any M: padded rows carry g = 0, so they add nothing to dw."""
    M, d = x.shape
    bm = block_for(M, bm)
    xp = pad_dim(x, bm, 0)
    gp = pad_dim(g, bm, 0)
    Mp = xp.shape[0]
    dx, dwp = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, d), x.dtype),
            jax.ShapeDtypeStruct((Mp // bm, d), jnp.float32),
        ],
        interpret=interpret,
    )(xp, w.reshape(1, d), gp)
    return dx[:M], jnp.sum(dwp, 0).astype(w.dtype)
