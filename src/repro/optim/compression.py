"""Gradient compression for cross-pod DP reduction.

LoRA gradients are already tiny (O(r·(d_in+d_out)) per layer), but at 1000+
node scale the cross-pod all-reduce latency still matters. Two schemes:

* :func:`to_bf16` — cast the DP all-reduce payload to bf16 (2× ICI bytes off)
  with an fp32 master accumulation after the reduce. Error-free enough for
  LoRA (empirically <1e-2 relative, tested).
* :func:`topk_sparsify` — rank-preserving top-k with error feedback, for the
  (beyond-paper) case of full-parameter fine-tuning where payloads are large.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _is_none(x):
    return x is None


def to_bf16(grads):
    return jax.tree_util.tree_map(
        lambda g: None if g is None else g.astype(jnp.bfloat16), grads,
        is_leaf=_is_none)


def from_bf16(grads):
    return jax.tree_util.tree_map(
        lambda g: None if g is None else g.astype(jnp.float32), grads,
        is_leaf=_is_none)


def topk_sparsify(grads, frac: float, error_state=None):
    """Keep top-``frac`` magnitude entries per leaf; residual goes to error
    feedback state so nothing is lost across steps (Stich et al. style)."""
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: None if g is None else jnp.zeros_like(g, jnp.float32),
            grads, is_leaf=_is_none)

    def one(g, e):
        if g is None:
            return None, None
        acc = g.astype(jnp.float32) + e
        k = max(1, int(acc.size * frac))
        flat = acc.reshape(-1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
        sent = (flat * mask).reshape(acc.shape)
        return sent, acc - sent

    flat, treedef = jax.tree_util.tree_flatten(grads, is_leaf=_is_none)
    errs = jax.tree_util.tree_leaves(error_state, is_leaf=_is_none)
    outs = [one(g, e) for g, e in zip(flat, errs)]
    sent = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return sent, new_err
