from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, sgd, sgd_momentum, make_optimizer,
)
from repro.optim import compression, schedules  # noqa: F401
