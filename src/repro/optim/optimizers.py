"""Optimizers over sparse (LoRA-only) gradient trees.

Gradient trees produced by the engines have ``None`` at frozen leaves, so
optimizer state is allocated only for trainable params — for LoRA fine-tuning
the state is O(r·(d_in+d_out)) per layer, which is the property that makes
the paper's setting DP-communication-cheap at scale (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (params, state)


def _is_none(x):
    return x is None


def _map(f, *trees):
    return jax.tree_util.tree_map(f, *trees, is_leaf=_is_none)


def sgd(lr: float | Callable[[jax.Array], jax.Array]) -> Optimizer:
    """Paper §5.1 uses plain SGD, lr 1e-4."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        new = _map(lambda p, g: p if g is None else
                   (p - lr_t * g.astype(p.dtype)), params, grads)
        return new, {"step": step}

    return Optimizer(init, update)


def sgd_momentum(lr, beta: float = 0.9) -> Optimizer:
    def init(params):
        m = _map(lambda p: None, params)  # filled lazily on first step
        return {"step": jnp.zeros((), jnp.int32), "m": m}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        m = _map(lambda g, m_, p: None if g is None else
                 (beta * (m_ if m_ is not None else jnp.zeros_like(p, jnp.float32))
                  + g.astype(jnp.float32)),
                 grads, state["m"], params)
        new = _map(lambda p, mi: p if mi is None else
                   (p - lr_t * mi).astype(p.dtype), params, m)
        return new, {"step": step, "m": m}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = _map(lambda p: None, params)
        return {"step": jnp.zeros((), jnp.int32), "m": z, "v": z}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr

        def upd_m(g, m_, p):
            if g is None:
                return None
            m0 = m_ if m_ is not None else jnp.zeros_like(p, jnp.float32)
            return b1 * m0 + (1 - b1) * g.astype(jnp.float32)

        def upd_v(g, v_, p):
            if g is None:
                return None
            v0 = v_ if v_ is not None else jnp.zeros_like(p, jnp.float32)
            return b2 * v0 + (1 - b2) * jnp.square(g.astype(jnp.float32))

        m = _map(upd_m, grads, state["m"], params)
        v = _map(upd_v, grads, state["v"], params)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def apply(p, mi, vi):
            if mi is None:
                return p
            upd = (mi / c1) / (jnp.sqrt(vi / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * upd).astype(p.dtype)

        return _map(apply, params, m, v), {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    return {"sgd": sgd, "sgd_momentum": sgd_momentum, "adamw": adamw}[name](lr, **kw)
