"""Shared model components (LoRA-adapted linears, attention, MLP, embeddings).

All trainable-path ops take an :class:`repro.api.policy.ExecutionPolicy`
(``policy``) selecting the backward regime: with the default ``structured``
backend every backward pass is the paper's hand-derived one
(``repro.core.structured``); ``pallas`` routes through the fused Pallas
kernels instead (``repro.kernels.ops`` — same structured math, per-op
fallback to the jnp path on unsupported shapes); ``plain`` is framework
autodiff; ``store_h`` the Table 5 ablation. Parameter pytrees are plain
nested dicts; LoRA-adapted linears carry ``{"w", "a", "b" [, "bias"]}``
where ``w``/``bias`` are frozen and ``a``/``b`` are trainable.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.policy import STRUCTURED, ExecutionPolicy
from repro.configs.base import ArchConfig
from repro.core import structured
from repro.core.flash import flash_attention
from repro.core.quant import maybe_dequant
from repro.kernels import ops as kops
from repro.kernels import rope as krope

Array = jax.Array

# Policy defaults for the flash threshold/chunking live on ExecutionPolicy
# (flash_min_seq / flash_chunk); these module constants document the
# defaults and seed them.
FLASH_MIN_SEQ = 1024
DEFAULT_CHUNK = 1024


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _split(key, n):
    return jax.random.split(key, n)


def mesh_axis_size(axis) -> int:
    """Size of a physical-mesh axis (or axis tuple) at trace time; 1 when no
    mesh context is installed (unit tests).

    Reads the mesh context installed by ``with mesh:`` via the public
    ``jax.interpreters.pxla.thread_resources`` handle (the supported
    spelling of the old ``jax._src.mesh`` probe).
    """
    if axis is None:
        return 1
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return 1
        if isinstance(axis, (tuple, list)):
            n = 1
            for a in axis:
                n *= mesh.shape[a]
            return n
        return mesh.shape[axis]
    except Exception:
        return 1


def _head_constrain(t, shard):
    """[B, H, N, D] → heads on the model axis when divisible, batch on DP.
    Keeps GSPMD from silently replicating k/v after the rope/transpose."""
    if shard is None:
        return t
    from jax.sharding import PartitionSpec as P
    msize = mesh_axis_size(shard["model"])
    hspec = shard["model"] if (msize > 1 and t.shape[1] % msize == 0) else None
    return jax.lax.with_sharding_constraint(
        t, P(shard["dp"], hspec, None, None))


def linear_params(key, d_in: int, d_out: int, cfg: ArchConfig, *,
                  lora: bool, bias: bool = False, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    k_w, k_a = _split(key, 2)
    p = {"w": (jax.random.normal(k_w, (d_in, d_out), dtype) * (d_in ** -0.5))}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    if lora:
        r = cfg.lora.rank
        p["a"] = jax.random.normal(k_a, (d_in, r), dtype) * (r ** -0.5)
        p["b"] = jnp.zeros((r, d_out), dtype)  # B=0: ΔW starts at 0 (LoRA std)
    return p


def apply_linear(p, x, cfg: ArchConfig, *,
                 policy: ExecutionPolicy = STRUCTURED, adapter_tiles=None):
    """LoRA linear. ``policy.backend``: "structured" (MeSP — h recomputed),
    "pallas" (MeSP via fused TPU kernels), "store_h" (Table 5 ablation),
    "plain" (MeBP — framework autodiff).

    ``p["w"]`` is either a dense frozen matrix, an int8 ``{"q", "scale"}``
    leaf or a packed 4-bit ``{"q4", "scale"}`` leaf
    (``core/quant.quantize_frozen``). The pallas path hands the quantized
    leaf to the dequant-in-VMEM kernels; the jnp paths dequantize to a dense
    matrix first (``maybe_dequant``) — same math, W0 materialized.

    Multi-tenant serving: when ``p["a"]/p["b"]`` are *stacked* adapter
    resident sets ([R, d_in, r] / [R, r, d_out] — AdapterStore), the int32
    ``adapter_tiles`` array routes each batch-slot tile to its adapter
    (``kernels/ops.lora_grouped_decode``; values may be runtime-traced so
    re-routing never recompiles). Decode only: x must be [B, 1, d].
    """
    backend = policy.backend
    bias = p.get("bias")
    if "a" in p and p["a"].ndim == 3:
        if adapter_tiles is None:
            raise ValueError("stacked adapters need adapter_tiles routing")
        if x.ndim != 2 and x.shape[-2] != 1:
            raise ValueError("grouped adapter routing is decode-only "
                             f"(got x {x.shape})")
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        bm = x2.shape[0] // adapter_tiles.shape[0]
        y = kops.lora_grouped_decode(x2, p["w"], p["a"], p["b"],
                                     adapter_tiles, bias, cfg.lora.scale,
                                     bm=bm, policy=policy)
        return y.reshape(*lead, y.shape[-1])
    if "a" in p:
        if backend == "pallas":
            return kops.lora_linear(x, p["w"], p["a"], p["b"], bias,
                                    cfg.lora.scale, policy=policy)
        w = maybe_dequant(p["w"], x.dtype)
        if backend == "plain":
            y = x @ w + cfg.lora.scale * ((x @ p["a"]) @ p["b"])
            return y + bias if bias is not None else y
        fn = structured.lora_linear_store_h if backend == "store_h" \
            else structured.lora_linear
        return fn(x, w, p["a"], p["b"], bias, cfg.lora.scale)
    y = x @ maybe_dequant(p["w"], x.dtype)
    if bias is not None:
        y = y + bias
    return y


def norm(p, x, cfg: ArchConfig, *, policy: ExecutionPolicy = STRUCTURED):
    """RMSNorm: structured (residual = x, rms recomputed), pallas (fused
    kernel, same residual contract) or plain autodiff."""
    if policy.backend == "plain":
        xf = x.astype(jnp.float32)
        rms = jnp.sqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + cfg.norm_eps)
        return ((xf / rms) * p.astype(jnp.float32)).astype(x.dtype)
    if policy.backend == "pallas":
        return kops.rmsnorm(x, p, cfg.norm_eps, policy=policy)
    return structured.rmsnorm(x, p, cfg.norm_eps)


def act_silu(x, policy: ExecutionPolicy):
    return x * jax.nn.sigmoid(x) if policy.backend == "plain" \
        else structured.silu(x)


def act_gelu(x, policy: ExecutionPolicy):
    return jax.nn.gelu(x, approximate=True) if policy.backend == "plain" \
        else structured.gelu(x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, N, H, D] (D even), positions: [N] or [B, N]."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [*, N, half]
    if ang.ndim == 2:  # [N, half] -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA + optional sliding window + KV cache)
# ---------------------------------------------------------------------------


def attention_params(key, cfg: ArchConfig, *, cross: bool = False,
                     lora: bool = True):
    ks = _split(key, 4)
    hd = cfg.resolved_head_dim
    tg = cfg.lora.targets
    return {
        "q": linear_params(ks[0], cfg.d_model, cfg.n_heads * hd, cfg,
                           lora=lora and "q" in tg, bias=cfg.qkv_bias),
        "k": linear_params(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg,
                           lora=lora and "k" in tg, bias=cfg.qkv_bias),
        "v": linear_params(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg,
                           lora=lora and "v" in tg, bias=cfg.qkv_bias),
        "o": linear_params(ks[3], cfg.n_heads * hd, cfg.d_model, cfg,
                           lora=lora and "o" in tg),
    }


def attention(p, x, cfg: ArchConfig, *, window: int = 0, causal: bool = True,
              cache: Optional[dict] = None, pos: Array | int = 0,
              kv_x: Optional[Array] = None, use_rope: bool = True,
              policy: ExecutionPolicy = STRUCTURED,
              shard=None, adapter_tiles=None) -> Tuple[Array, Optional[dict]]:
    """Multi-head attention with the structured backward.

    ``cache`` (decode): {"k": [B,Hkv,S,D], "v": ..., "len": int32 — scalar,
    or [B] per-slot lengths for continuous batching (every slot at its own
    position; writes and masks then vectorize per row)}.
    ``kv_x``: source for k/v (cross-attention) — defaults to x.
    ``adapter_tiles``: multi-tenant decode routing for stacked q/k/v/o
    adapters (see :func:`apply_linear`).
    """
    B, N, _ = x.shape
    hd = cfg.resolved_head_dim
    src = x if kv_x is None else kv_x
    Nk = src.shape[1]
    lin = functools.partial(apply_linear, cfg=cfg, policy=policy,
                            adapter_tiles=adapter_tiles)

    q = lin(p["q"], x).reshape(B, N, cfg.n_heads, hd)
    k = lin(p["k"], src).reshape(B, Nk, cfg.n_kv_heads, hd)
    v = lin(p["v"], src).reshape(B, Nk, cfg.n_kv_heads, hd)

    rope_tabs = None
    if use_rope:
        parr = jnp.asarray(pos)
        off = parr[..., None] if parr.ndim else parr  # [B,1] when per-slot
        qpos = jnp.arange(N) + off
        kpos = jnp.arange(Nk) + (off if kv_x is None else 0)
        fuse = (policy.backend == "pallas" and policy.fuse_rope
                and cache is None and kv_x is None and hd % 2 == 0)
        if fuse:
            # rotation deferred into the flash kernels: the [N, D/2] cos/sin
            # tables stream per tile and q/k are rotated in VMEM — the
            # rotated copies never round-trip through HBM (kernels/rope.py)
            rope_tabs = krope.rope_tables(qpos, cfg.rope_theta, hd)
        else:
            q = rope(q, qpos, cfg.rope_theta)
            k = rope(k, kpos, cfg.rope_theta)

    q = _head_constrain(q.transpose(0, 2, 1, 3), shard)  # [B,H,N,D]
    k = _head_constrain(k.transpose(0, 2, 1, 3), shard)
    v = _head_constrain(v.transpose(0, 2, 1, 3), shard)

    new_cache = None
    if cache is not None:
        if window > 0 and cache["k"].shape[2] == window:
            # ring buffer: sliding-window layers keep only ``window`` slots
            # (long_500k decode: 512× less cache for gemma3 local layers)
            slot = cache["len"] % window
            kc = _cache_write(cache["k"], k, slot)
            vc = _cache_write(cache["v"], v, slot)
            new_cache = {"k": kc, "v": vc, "len": cache["len"] + N}
            out = _ring_attend(q, kc, vc, cache["len"], window)
        else:
            # linear cache: append k/v at ``len`` and attend over valid slots
            kc = _cache_write(cache["k"], k, cache["len"])
            vc = _cache_write(cache["v"], v, cache["len"])
            new_cache = {"k": kc, "v": vc, "len": cache["len"] + N}
            out = structured.sdpa(q, kc, vc, window, causal,
                                  cache["len"], cache["len"] + N)
    elif policy.backend == "plain":
        out = structured._sdpa_ref(q, k, v, window, causal, 0, None)
    elif policy.backend == "pallas":
        # kernel flash attention (fwd + lse-driven bwd); falls back to the
        # structured sdpa for short sequences / unsupported layouts (the
        # fallback applies any deferred rope tables via jnp first)
        out = kops.sdpa(q, k, v, causal=causal, window=window, policy=policy,
                        rope=rope_tabs)
    elif N >= policy.flash_min_seq:
        out = flash_attention(q, k, v, window, causal,
                              policy.flash_chunk, policy.flash_chunk)
    else:
        out = structured.sdpa(q, k, v, window, causal)

    out = out.transpose(0, 2, 1, 3).reshape(B, N, cfg.n_heads * hd)
    return lin(p["o"], out), new_cache


def _cache_write(c, u, ln):
    """Write ``u`` into cache ``c`` ([B,Hkv,S,D]) at slot offset ``ln`` —
    a scalar (whole batch at one position, training/simple decode) or a
    [B] vector (continuous batching: every slot at its own length)."""
    if jnp.ndim(ln) == 0:
        return jax.lax.dynamic_update_slice_in_dim(c, u, ln, 2)
    row = lambda ci, ui, li: jax.lax.dynamic_update_slice_in_dim(ci, ui, li, 1)
    return jax.vmap(row)(c, u, ln)


def _ring_attend(q, kc, vc, qpos, window: int):
    """Decode attention over a ring-buffer cache (keys roped at write time).

    q: [B,H,1,D]; kc/vc: [B,Hkv,W,D]; slot s holds absolute position
    p(s) = qpos − ((qpos − s) mod W), valid when 0 ≤ p(s) and p(s) > qpos−W.
    ``qpos`` may be a [B] vector (per-slot decode).
    """
    B, H, _, D = q.shape
    Hkv, W = kc.shape[1], kc.shape[2]
    G = H // Hkv
    slots = jnp.arange(W)
    qp = qpos[..., None] if jnp.ndim(qpos) else qpos
    pos = qp - jnp.mod(qp - slots, W)
    valid = (pos >= 0) & (pos > qp - W) & (pos <= qp)
    if valid.ndim == 2:                     # [B,W] -> [B,1,1,1,W]
        valid = valid[:, None, None, None, :]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.reshape(B, Hkv, G, 1, D), kc,
                   preferred_element_type=jnp.float32) / jnp.sqrt(D)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, 1, D).astype(q.dtype)


def make_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype, *,
                  window: int = 0, per_slot: bool = False) -> dict:
    """KV cache; sliding-window layers get a ring buffer of ``window`` slots
    when that is smaller than the full length. ``per_slot``: track a [B]
    length vector instead of one scalar, so continuous batching can hold
    every slot at its own position."""
    hd = cfg.resolved_head_dim
    slots = window if (window and window < max_len) else max_len
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, slots, hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, slots, hd), dtype),
        "len": jnp.zeros((batch,) if per_slot else (), jnp.int32),
    }


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU) — LoRA on gate/up/down, SiLU via structured backward
# ---------------------------------------------------------------------------


def mlp_params(key, cfg: ArchConfig, d_ff: Optional[int] = None, *,
               act: str = "silu", lora: bool = True):
    ks = _split(key, 3)
    d_ff = d_ff or cfg.d_ff
    tg = cfg.lora.targets
    p = {
        "gate": linear_params(ks[0], cfg.d_model, d_ff, cfg, lora=lora and "gate" in tg),
        "up": linear_params(ks[1], cfg.d_model, d_ff, cfg, lora=lora and "up" in tg),
        "down": linear_params(ks[2], d_ff, cfg.d_model, cfg, lora=lora and "down" in tg),
    }
    if act == "gelu":  # whisper: plain (non-gated) MLP, keep 'up/down' only
        p = {
            "up": linear_params(ks[1], cfg.d_model, d_ff, cfg, lora=lora and "up" in tg),
            "down": linear_params(ks[2], d_ff, cfg.d_model, cfg, lora=lora and "down" in tg),
        }
    return p


def mlp(p, x, cfg: ArchConfig, *, policy: ExecutionPolicy = STRUCTURED,
        adapter_tiles=None):
    lin = functools.partial(apply_linear, cfg=cfg, policy=policy,
                            adapter_tiles=adapter_tiles)
    if "gate" in p:
        g = lin(p["gate"], x)
        u = lin(p["up"], x)
        return lin(p["down"], act_silu(g, policy) * u)
    u = lin(p["up"], x)
    return lin(p["down"], act_gelu(u, policy))


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_h = _split(key, 2)
    p = {"tok": jax.random.normal(k_e, (cfg.vocab, cfg.d_model), dtype) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k_h, (cfg.d_model, cfg.vocab), dtype) \
            * (cfg.d_model ** -0.5)
    return p


def embed(p, tokens, cfg: ArchConfig):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.name.startswith(("gemma", "recurrentgemma")):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma convention
    return x


def unembed(p, x, cfg: ArchConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return (x @ w).astype(jnp.float32)
