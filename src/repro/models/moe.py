"""Mixture-of-Experts MLP with grouped, EP-shardable dispatch.

Dispatch is **grouped by sequence** (group = batch row, which is already
data-parallel-sharded): routing, capacity assignment and the scatter into
per-expert buffers are all per-group operations, so GSPMD keeps them on the
data axis and inserts exactly one all-to-all pair per layer when the
``[B, E, C, d]`` buffer is resharded to expert-parallel ``[E, B·C, d]``
(experts on the ``model`` axis).

(History: a first implementation used a *global* argsort-based dispatch —
GSPMD cannot shard a global sort, so every device materialized the full
[T·k, d] dispatch array and 64 GB all-reduces appeared per layer. See
EXPERIMENTS.md §Perf iteration olmoe-1.)

The expert FFN is a batched per-expert LoRA MLP whose backward is the
paper's structured one (per-expert ``h = x@A`` recomputed, never stored).
Capacity-dropped tokens contribute zero (residual passes through),
Switch-style; capacity is per group: ``C = N·top_k/E · 1.25``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.policy import STRUCTURED, ExecutionPolicy
from repro.configs.base import ArchConfig
from repro.models import layers

CAPACITY_FACTOR = 1.25


def moe_params(key, cfg: ArchConfig, *, lora: bool = True):
    assert cfg.moe is not None
    m = cfg.moe
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    d, f, E = cfg.d_model, m.d_expert, m.n_experts
    r = cfg.lora.rank
    tg = cfg.lora.targets

    def expert_stack(k, d_in, d_out, with_lora):
        kw, ka = jax.random.split(k)
        p = {"w": jax.random.normal(kw, (E, d_in, d_out), dtype) * (d_in ** -0.5)}
        if with_lora:
            p["a"] = jax.random.normal(ka, (E, d_in, r), dtype) * (r ** -0.5)
            p["b"] = jnp.zeros((E, r, d_out), dtype)
        return p

    p = {
        "router": jax.random.normal(ks[0], (d, E), dtype) * (d ** -0.5),
        "gate": expert_stack(ks[1], d, f, lora and "gate" in tg),
        "up": expert_stack(ks[2], d, f, lora and "up" in tg),
        "down": expert_stack(ks[3], f, d, lora and "down" in tg),
    }
    if m.n_shared:
        # shared experts fused into one dense gated MLP of width n_shared·f
        p["shared"] = layers.mlp_params(ks[4], cfg, d_ff=m.n_shared * f, lora=lora)
    return p


def _capacity(n_per_group: int, m) -> int:
    c = int(n_per_group * m.top_k / m.n_experts * CAPACITY_FACTOR)
    return max(8, -(-c // 8) * 8)


def _maybe_constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_mlp(p, x, cfg: ArchConfig, *,
            policy: ExecutionPolicy = STRUCTURED, shard=None):
    """x: [B, N, d] -> [B, N, d].

    ``shard``: optional dict {"dp": axes, "model": axis} enabling explicit
    sharding constraints on the dispatch buffers (group dim on DP, expert
    dim on model) — set by the production launchers, None in unit tests.
    """
    from jax.sharding import PartitionSpec as P
    from repro.core import structured

    m = cfg.moe
    B, N, d = x.shape
    k = m.top_k
    E = m.n_experts
    # groups = (batch row × sequence shard): with the activations sharded
    # P(dp, model, ·) between blocks, routing/capacity/scatter are then
    # FULLY LOCAL to every device — zero collectives before the EP
    # all-to-all (§Perf iteration olmoe-3)
    sp = shard.get("sp", 1) if shard else 1
    sp = sp if N % sp == 0 else 1
    Ng = N // sp
    C = _capacity(Ng, m)
    xg = x.reshape(B, sp, Ng, d)

    logits = (xg @ p["router"]).astype(jnp.float32)          # [B,sp,Ng,E]
    weights, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    weights = (weights / jnp.sum(weights, -1, keepdims=True)).astype(x.dtype)

    # --- per-group capacity assignment (no global sort) --------------------
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # [B,sp,Ng,k,E]
    flat_oh = onehot.reshape(B, sp, Ng * k, E)
    pos_in_e = jnp.cumsum(flat_oh, axis=2) - flat_oh         # exclusive cumsum
    pos = jnp.sum(pos_in_e * flat_oh, -1).reshape(B, sp, Ng, k)
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)

    # --- scatter into [B, sp, E, C, d] (groups stay on (dp, model)) --------
    vals = (xg[:, :, :, None, :] * keep[..., None].astype(x.dtype))
    vals = vals.reshape(B, sp, Ng * k, d)
    eid = idx.reshape(B, sp, Ng * k)
    slot = pos_c.reshape(B, sp, Ng * k)

    def scatter_group(v, e, s):
        return jnp.zeros((E, C, d), x.dtype).at[e, s].add(v)

    buf = jax.vmap(jax.vmap(scatter_group))(vals, eid, slot)  # [B,sp,E,C,d]
    dp = shard["dp"] if shard else None
    if shard:
        buf = _maybe_constrain(buf, P(dp, shard["model"], None, None, None))

    # --- reshard to expert-parallel and run the expert LoRA MLP ------------
    ebuf = buf.transpose(2, 0, 1, 3, 4).reshape(E, B * sp * C, d)
    if shard:
        # expert dim on model, token rows on DP: one all-to-all pair/layer
        ebuf = _maybe_constrain(ebuf, P(shard["model"], dp, None))

    store_h = policy.backend == "store_h"

    def elin(q, z):
        # per-expert [E,·,·] weights. pallas backend: the grouped kernel
        # family (kernels/lora_grouped.py) runs all experts in one launch,
        # dequantizing int8 expert stacks tile-wise in VMEM — a dense
        # per-expert W0 never exists in HBM (jaxpr-asserted in tests).
        from repro.core.quant import maybe_dequant
        if "a" in q:
            if policy.backend == "pallas":
                from repro.kernels import ops as kops
                return kops.lora_grouped_linear(z, q["w"], q["a"], q["b"],
                                                cfg.lora.scale, policy=policy)
            w = maybe_dequant(q["w"], z.dtype)
            if policy.backend == "plain":
                return z @ w + cfg.lora.scale * ((z @ q["a"]) @ q["b"])
            fn = structured.lora_linear_store_h if store_h \
                else structured.lora_linear
            return fn(z, w, q["a"], q["b"], None, cfg.lora.scale)
        return z @ maybe_dequant(q["w"], z.dtype)

    hidden = layers.act_silu(elin(p["gate"], ebuf), policy) * elin(p["up"], ebuf)
    y_ebuf = elin(p["down"], hidden)                         # [E, B·C, d]

    # --- return path: reshard back to groups, gather, combine --------------
    if shard:
        y_ebuf = _maybe_constrain(y_ebuf, P(shard["model"], dp, None))
    y_buf = y_ebuf.reshape(E, B, sp, C, d).transpose(1, 2, 0, 3, 4)
    if shard:
        y_buf = _maybe_constrain(y_buf,
                                 P(dp, shard["model"], None, None, None))

    def gather_group(yb, e, s):
        return yb[e, s]                                      # [Ng·k, d]

    out_slots = jax.vmap(jax.vmap(gather_group))(y_buf, eid, slot)
    out_slots = out_slots.reshape(B, sp, Ng, k, d) * \
        (weights * keep.astype(x.dtype))[..., None]
    out = jnp.sum(out_slots, axis=3).reshape(B, N, d)

    if "shared" in p:
        out = out + layers.mlp(p["shared"], x, cfg, policy=policy)
    return out


def aux_load_balance_loss(p, x, cfg: ArchConfig):
    """Switch-style load-balance auxiliary (exposed for training configs)."""
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    logits = (x.reshape(T, -1) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, m.top_k)
    frac = jnp.bincount(idx.reshape(-1), length=m.n_experts) / (T * m.top_k)
    imp = jnp.mean(probs, 0)
    return m.n_experts * jnp.sum(frac * imp)
