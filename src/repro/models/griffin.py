"""Griffin / RecurrentGemma blocks: RG-LRU recurrence + local attention
[arXiv:2402.19427].

The RG-LRU diagonal recurrence  h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)
is computed with ``jax.lax.associative_scan`` (O(log N) depth — TPU-friendly,
unlike a sequential per-token scan). Blocks follow the 2:1 (R,R,A) pattern.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.policy import STRUCTURED, ExecutionPolicy
from repro.configs.base import ArchConfig
from repro.models import layers

CONV_WIDTH = 4
LRU_C = 8.0  # RG-LRU decay sharpness constant


def recurrent_block_params(key, cfg: ArchConfig, *, lora: bool = True):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    tg = cfg.lora.targets
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln": jnp.ones((d,), dtype),
        "x_proj": layers.linear_params(ks[0], d, w, cfg, lora=lora and "q" in tg),
        "gate_proj": layers.linear_params(ks[1], d, w, cfg, lora=lora and "gate" in tg),
        "conv_w": jax.random.normal(ks[2], (CONV_WIDTH, w), dtype) * 0.1,
        "conv_b": jnp.zeros((w,), dtype),
        # RG-LRU gates
        "rg_w": layers.linear_params(ks[3], w, w, cfg, lora=False),
        "in_w": layers.linear_params(ks[4], w, w, cfg, lora=False),
        "lam": jnp.full((w,), 2.0, dtype),  # Λ: softplus → decay rates
        "out_proj": layers.linear_params(ks[5], w, d, cfg, lora=lora and "o" in tg),
    }


def _causal_conv(x, w, b, state: Optional[jax.Array]):
    """Depthwise causal conv, width CONV_WIDTH. x: [B,N,W].

    ``state``: [B, CONV_WIDTH-1, W] trailing inputs (decode). Returns
    (y, new_state).
    """
    if state is None:
        xp = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
        new_state = None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(CONV_WIDTH - 1):]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_WIDTH))
    return y + b, new_state


def rg_lru(x, gates_r, gates_i, lam, state: Optional[jax.Array]):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t ⊙ x_t);  log a_t = -c·softplus(Λ)·r_t.

    x/gates: [B,N,W] (train/prefill) or [B,1,W] with ``state`` [B,W] (decode).
    """
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(gates_r.astype(jnp.float32))
    i = jax.nn.sigmoid(gates_i.astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if state is not None:
        h = a[:, 0] * state + gated[:, 0]
        return h[:, None].astype(x.dtype), h
    # associative scan over time: (a, b) ∘ (a', b') = (a·a', a'·b + b')
    def combine(u, v):
        return (v[0] * u[0], v[0] * u[1] + v[1])
    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), None


def recurrent_block(p, x, cfg: ArchConfig, *, state=None,
                    policy: ExecutionPolicy = STRUCTURED):
    """Griffin recurrent block. state: {"conv": [B,3,W], "lru": [B,W]}."""
    xin = layers.norm(p["ln"], x, cfg, policy=policy)
    main = layers.apply_linear(p["x_proj"], xin, cfg, policy=policy)
    gate = layers.act_gelu(
        layers.apply_linear(p["gate_proj"], xin, cfg, policy=policy), policy)
    conv_state = None if state is None else state["conv"]
    main, conv_new = _causal_conv(main, p["conv_w"], p["conv_b"], conv_state)
    gr = layers.apply_linear(p["rg_w"], main, cfg, policy=policy)
    gi = layers.apply_linear(p["in_w"], main, cfg, policy=policy)
    lru_state = None if state is None else state["lru"]
    h, lru_new = rg_lru(main, gr, gi, p["lam"], lru_state)
    y = layers.apply_linear(p["out_proj"], h * gate, cfg, policy=policy)
    new_state = None if state is None else {"conv": conv_new, "lru": lru_new}
    return x + y, new_state


def make_recurrent_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    w = cfg.hybrid.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, w), dtype),
        "lru": jnp.zeros((batch, w), jnp.float32),
    }
