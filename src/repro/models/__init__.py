from repro.models import griffin, layers, model, moe, rwkv6  # noqa: F401
