"""Model assembly: init / forward / loss / decode for all assigned families.

Layout conventions
------------------
* Homogeneous stacks (dense, moe, vlm, ssm) are ``lax.scan``-ed over stacked
  block params ``[L, ...]`` with ``jax.checkpoint`` around the block body:
  **only block inputs are stored** across the forward pass — the paper's
  block-sequential checkpointing (§4.3) expressed as scan-over-layers.
* Patterned stacks (gemma3 5:1 local:global, recurrentgemma R,R,A) scan over
  *groups* (one pattern period, params ``[n_groups, ...]``) so per-layer
  window sizes / block kinds stay static inside the group body.
* ``policy`` (:class:`repro.api.policy.ExecutionPolicy`) selects the
  backward regime (``policy.backend``: "structured" = MeSP hand-derived
  custom_vjp rules, "pallas" = MeSP via the fused TPU kernels in
  ``repro.kernels`` — sparse-grid flash attention, optionally with RoPE
  applied inside the kernels via ``policy.fuse_rope``), the activation
  sharding constraint (``policy.act_spec``) and the remat schedule
  (``policy.remat``). "plain" = MeBP framework autodiff, "store_h" =
  paper Table 5 ablation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.policy import BACKENDS, STRUCTURED, ExecutionPolicy  # noqa: F401  (BACKENDS re-exported)
from repro.configs.base import ArchConfig
from repro.core import quant, structured
from repro.models import griffin, layers, moe as moe_lib, rwkv6

Array = jax.Array


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------


def dense_block(bp, x, cfg, *, window=0, policy: ExecutionPolicy = STRUCTURED,
                cache=None, pos=0, shard=None, adapter_tiles=None):
    h, new_cache = layers.attention(
        bp["attn"], layers.norm(bp["ln1"], x, cfg, policy=policy), cfg,
        window=window, cache=cache, pos=pos, policy=policy, shard=shard,
        adapter_tiles=adapter_tiles)
    x = x + h
    x = x + layers.mlp(bp["mlp"],
                       layers.norm(bp["ln2"], x, cfg, policy=policy),
                       cfg, policy=policy, adapter_tiles=adapter_tiles)
    return x, new_cache


def moe_block(bp, x, cfg, *, window=0, policy: ExecutionPolicy = STRUCTURED,
              cache=None, pos=0, shard=None, adapter_tiles=None):
    h, new_cache = layers.attention(
        bp["attn"], layers.norm(bp["ln1"], x, cfg, policy=policy), cfg,
        window=window, cache=cache, pos=pos, policy=policy, shard=shard,
        adapter_tiles=adapter_tiles)
    x = x + h
    x = x + moe_lib.moe_mlp(bp["moe"],
                            layers.norm(bp["ln2"], x, cfg, policy=policy),
                            cfg, policy=policy, shard=shard)
    return x, new_cache


def _block_params(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    if kind == "dense":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": layers.attention_params(ks[0], cfg),
                "ln2": jnp.ones((d,), dtype),
                "mlp": layers.mlp_params(ks[1], cfg)}
    if kind == "moe":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": layers.attention_params(ks[0], cfg),
                "ln2": jnp.ones((d,), dtype),
                "moe": moe_lib.moe_params(ks[1], cfg)}
    if kind == "moe_dense0":  # deepseek layer 0: dense FFN of matched width
        m = cfg.moe
        return {"ln1": jnp.ones((d,), dtype),
                "attn": layers.attention_params(ks[0], cfg),
                "ln2": jnp.ones((d,), dtype),
                "mlp": layers.mlp_params(
                    ks[1], cfg, d_ff=m.d_expert * (m.top_k + m.n_shared))}
    if kind == "rwkv":
        return rwkv6.rwkv_block_params(key, cfg)
    if kind == "recurrent":
        return griffin.recurrent_block_params(key, cfg)
    if kind == "local_attn":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": layers.attention_params(ks[0], cfg),
                "ln2": jnp.ones((d,), dtype),
                "mlp": layers.mlp_params(ks[1], cfg)}
    if kind == "enc":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": layers.attention_params(ks[0], cfg),
                "ln2": jnp.ones((d,), dtype),
                "mlp": layers.mlp_params(ks[1], cfg, act="gelu")}
    if kind == "dec":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": layers.attention_params(ks[0], cfg),
                "lnx": jnp.ones((d,), dtype),
                "xattn": layers.attention_params(ks[1], cfg, cross=True),
                "ln2": jnp.ones((d,), dtype),
                "mlp": layers.mlp_params(ks[2], cfg, act="gelu")}
    raise ValueError(kind)


def _stack_params(key, cfg, kind, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_params(k, cfg, kind))(keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig, *, quantize: Optional[str] = None):
    """Init the param pytree; ``quantize`` ("int8", or packed "int4"/"nf4")
    converts every frozen ``w`` leaf to its ``core/quant`` format dict
    (``{"q", "scale"}`` int8; ``{"q4", "scale", ...}`` packed 4-bit) — LoRA
    factors, biases, norms and embeddings stay in ``cfg.dtype``."""
    k_emb, k_blk, k_tail, k_enc = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p = {"embed": layers.embed_params(k_emb, cfg),
         "final_norm": jnp.ones((cfg.d_model,), dtype)}

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.window_pattern:  # gemma3: group per pattern period
            gsz = len(cfg.window_pattern)
            assert cfg.n_layers % gsz == 0
            # group leaves are stacked [n_groups, gsz, ...]
            p["groups"] = jax.vmap(
                lambda k: jax.vmap(lambda kk: _block_params(kk, cfg, "dense"))(
                    jax.random.split(k, gsz)))(
                jax.random.split(k_blk, cfg.n_layers // gsz))
        else:
            p["blocks"] = _stack_params(k_blk, cfg, "dense", cfg.n_layers)
    elif fam == "moe":
        n = cfg.n_layers
        if cfg.moe.first_layer_dense:
            p["block0"] = _block_params(k_tail, cfg, "moe_dense0")
            n -= 1
        p["blocks"] = _stack_params(k_blk, cfg, "moe", n)
    elif fam == "ssm":
        p["blocks"] = _stack_params(k_blk, cfg, "rwkv", cfg.n_layers)
    elif fam == "hybrid":
        pat = cfg.hybrid.pattern
        gsz = len(pat)
        n_groups = cfg.n_layers // gsz
        n_tail = cfg.n_layers - n_groups * gsz

        def group_params(k):
            kk = jax.random.split(k, gsz)
            return {f"l{i}": _block_params(
                kk[i], cfg, "recurrent" if pat[i] == "R" else "local_attn")
                for i in range(gsz)}

        p["groups"] = jax.vmap(group_params)(jax.random.split(k_blk, n_groups))
        p["tail"] = [
            _block_params(k, cfg, "recurrent" if pat[i % gsz] == "R" else "local_attn")
            for i, k in enumerate(jax.random.split(k_tail, n_tail))]
    elif fam == "audio":
        ec = cfg.encdec
        p["enc_blocks"] = _stack_params(k_enc, cfg, "enc", ec.encoder_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["blocks"] = _stack_params(k_blk, cfg, "dec", cfg.n_layers)
    else:
        raise ValueError(fam)
    return quant.quantize_params(p, quantize)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _constrain(x, act_spec):
    """Apply a block-boundary activation sharding constraint (Megatron SP:
    sequence on the model axis between blocks). No-op when act_spec is None."""
    if act_spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, act_spec)


def _scan_ckpt(body, x, stacked, act_spec=None, remat=True):
    """scan over stacked block params with per-block rematerialization.

    Storing only the scan carry (= block inputs) is the paper's §4.3
    checkpoint strategy; ``act_spec`` shards those stored checkpoints.
    """
    f = jax.checkpoint(body) if remat else body

    def step(c, bp):
        return _constrain(f(c, bp), act_spec), None

    x, _ = jax.lax.scan(step, _constrain(x, act_spec), stacked)
    return x


def _encoder_forward(params, cfg, frames, policy):
    """Whisper encoder over precomputed frame embeddings [B, T, d]."""
    pos = _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    x = frames + pos

    def body(x, bp):
        h, _ = layers.attention(bp["attn"],
                                layers.norm(bp["ln1"], x, cfg, policy=policy),
                                cfg, causal=False, use_rope=False,
                                policy=policy)
        x = x + h
        return x + layers.mlp(bp["mlp"],
                              layers.norm(bp["ln2"], x, cfg, policy=policy),
                              cfg, policy=policy)

    x = _scan_ckpt(body, x, params["enc_blocks"], remat=policy.remat)
    return layers.norm(params["enc_norm"], x, cfg, policy=policy)


def _sinusoid(n, d, dtype):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / (10000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)[None]


def forward(params, cfg: ArchConfig, tokens: Array, *,
            policy: ExecutionPolicy = STRUCTURED,
            frontend_embeds: Optional[Array] = None,
            enc_frames: Optional[Array] = None) -> Array:
    """Full-sequence forward -> logits [B, N(+frontend), vocab] (fp32)."""
    act_spec = policy.act_spec
    remat = policy.remat
    x = layers.embed(params["embed"], tokens, cfg)
    if frontend_embeds is not None:  # vlm: precomputed patch embeddings
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)

    shard = None
    if act_spec is not None:
        shard = {"dp": act_spec[0], "model": act_spec[1],
                 "sp": layers.mesh_axis_size(act_spec[1])}

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.window_pattern:
            gsz = len(cfg.window_pattern)

            def gbody(x, gp):
                for i in range(gsz):
                    bp = jax.tree_util.tree_map(lambda t: t[i], gp)
                    x, _ = dense_block(bp, x, cfg,
                                       window=cfg.window_pattern[i],
                                       policy=policy, shard=shard)
                return x

            x = _scan_ckpt(gbody, x, params["groups"], act_spec, remat)
        else:
            def body(x, bp):
                return dense_block(bp, x, cfg, policy=policy, shard=shard)[0]

            x = _scan_ckpt(body, x, params["blocks"], act_spec, remat)
    elif fam == "moe":
        if "block0" in params:
            x, _ = dense_block(params["block0"], x, cfg, policy=policy,
                               shard=shard)

        def body(x, bp):
            return moe_block(bp, x, cfg, policy=policy, shard=shard)[0]

        x = _scan_ckpt(body, x, params["blocks"], act_spec, remat)
    elif fam == "ssm":
        def body(x, bp):
            return rwkv6.rwkv_block(bp, x, cfg, policy=policy)[0]

        x = _scan_ckpt(body, x, params["blocks"], act_spec, remat)
    elif fam == "hybrid":
        pat = cfg.hybrid.pattern
        gsz = len(pat)

        def gbody(x, gp):
            for i in range(gsz):
                bp = gp[f"l{i}"]
                if pat[i] == "R":
                    x, _ = griffin.recurrent_block(bp, x, cfg, policy=policy)
                else:
                    x, _ = dense_block(bp, x, cfg,
                                       window=cfg.hybrid.window,
                                       policy=policy, shard=shard)
            return x

        x = _scan_ckpt(gbody, x, params["groups"], act_spec, remat)
        n_groups = jax.tree_util.tree_leaves(params["groups"])[0].shape[0]
        for i, bp in enumerate(params["tail"]):
            li = n_groups * gsz + i
            if pat[li % gsz] == "R":
                x, _ = griffin.recurrent_block(bp, x, cfg, policy=policy)
            else:
                x, _ = dense_block(bp, x, cfg, window=cfg.hybrid.window,
                                   policy=policy)
    elif fam == "audio":
        assert enc_frames is not None, "audio arch needs enc_frames"
        enc_out = _encoder_forward(params, cfg, enc_frames, policy)
        x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)

        def body(x, bp):
            h, _ = layers.attention(bp["attn"],
                                    layers.norm(bp["ln1"], x, cfg,
                                                policy=policy),
                                    cfg, use_rope=False, policy=policy)
            x = x + h
            h, _ = layers.attention(bp["xattn"],
                                    layers.norm(bp["lnx"], x, cfg,
                                                policy=policy),
                                    cfg, causal=False, kv_x=enc_out,
                                    use_rope=False, policy=policy)
            x = x + h
            return x + layers.mlp(bp["mlp"],
                                  layers.norm(bp["ln2"], x, cfg,
                                              policy=policy),
                                  cfg, policy=policy)

        x = _scan_ckpt(body, x, params["blocks"], act_spec, remat)
    else:
        raise ValueError(fam)

    x = layers.norm(params["final_norm"], x, cfg, policy=policy)
    return layers.unembed(params["embed"], x, cfg)


def loss_fn(params, cfg: ArchConfig, batch: dict, *,
            policy: ExecutionPolicy = STRUCTURED) -> Array:
    """Mean next-token CE. batch: tokens/labels [B,N] (+frontend/frames)."""
    logits = forward(params, cfg, batch["tokens"], policy=policy,
                     frontend_embeds=batch.get("frontend_embeds"),
                     enc_frames=batch.get("enc_frames"))
    labels = batch["labels"]
    if cfg.frontend_tokens and batch.get("frontend_embeds") is not None:
        # frontend prefix carries no labels
        pad = jnp.full(labels.shape[:1] + (batch["frontend_embeds"].shape[1],),
                       -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return structured.softmax_xent(logits, labels)


# ---------------------------------------------------------------------------
# decode (serve_step): one new token against a cache of seq_len
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               per_slot: bool = False):
    """Stacked per-layer decode state. ``per_slot``: [B] length vectors
    instead of one scalar (continuous batching — attention-cache families
    only)."""
    dtype = jnp.dtype(cfg.dtype)

    def stack(make, n):
        return jax.vmap(lambda _: make())(jnp.arange(n))

    fam = cfg.family
    if per_slot and fam not in ("dense", "vlm", "moe"):
        raise ValueError(f"per_slot decode caches unsupported for {fam!r}")
    if fam in ("dense", "vlm", "moe"):
        kv = lambda w=0: layers.make_kv_cache(cfg, batch, max_len, dtype,
                                              window=w, per_slot=per_slot)
        if cfg.window_pattern:
            # ring (window-sized) and linear (full-length) caches differ in
            # shape → keyed per pattern position, stacked over groups only
            gsz = len(cfg.window_pattern)

            def group_cache():
                return {f"l{i}": kv(cfg.window_pattern[i]) for i in range(gsz)}

            return {"groups": stack(group_cache, cfg.n_layers // gsz)}
        n = cfg.n_layers - (1 if (cfg.moe and cfg.moe.first_layer_dense) else 0)
        c = {"blocks": stack(kv, n)}
        if cfg.moe and cfg.moe.first_layer_dense:
            c["block0"] = kv()
        return c
    if fam == "ssm":
        return {"blocks": stack(lambda: rwkv6.make_rwkv_state(cfg, batch, dtype),
                                cfg.n_layers)}
    if fam == "hybrid":
        pat = cfg.hybrid.pattern
        gsz = len(pat)
        n_groups = cfg.n_layers // gsz
        window = cfg.hybrid.window

        def group_state():
            return {f"l{i}": (griffin.make_recurrent_state(cfg, batch, dtype)
                              if pat[i] == "R"
                              else layers.make_kv_cache(cfg, batch, max_len,
                                                        dtype, window=window))
                    for i in range(gsz)}

        tail = []
        for i in range(cfg.n_layers - n_groups * gsz):
            li = n_groups * gsz + i
            tail.append(griffin.make_recurrent_state(cfg, batch, dtype)
                        if pat[li % gsz] == "R"
                        else layers.make_kv_cache(cfg, batch, max_len, dtype,
                                                  window=window))
        return {"groups": stack(group_state, n_groups), "tail": tail}
    if fam == "audio":
        return {"blocks": stack(lambda: layers.make_kv_cache(cfg, batch, max_len, dtype),
                                cfg.n_layers),
                "enc_out": jnp.zeros((batch, cfg.encdec.encoder_seq, cfg.d_model),
                                     dtype)}
    raise ValueError(fam)


def decode_step(params, cfg: ArchConfig, cache, tokens: Array, *,
                policy: ExecutionPolicy = STRUCTURED, adapter_tiles=None):
    """One decode step. tokens: [B, 1]. Returns (logits [B,1,V], new cache).

    ``policy`` selects the forward execution regime (inference: the
    structured custom_vjp forwards == plain forwards; quantized params
    carry their format in the tree, dequantized per the policy's backend).

    ``adapter_tiles``: int32 [B // bm] per-tile adapter routing for stacked
    multi-tenant LoRA params (see :func:`layers.apply_linear`); requires a
    ``per_slot`` cache so co-batched requests sit at independent positions.
    """
    x = layers.embed(params["embed"], tokens, cfg)
    fam = cfg.family
    new_cache = dict(cache)
    if adapter_tiles is not None and fam not in ("dense", "vlm"):
        # moe: expert stacks already consume the [E, ., .] group axis —
        # per-tenant expert adapters would need (expert × tenant) grouping
        raise ValueError(f"adapter routing unsupported for {fam!r}")

    if fam in ("dense", "vlm", "moe"):
        if cfg.window_pattern:
            gsz = len(cfg.window_pattern)

            def gbody(x, gs):
                gp, gc = gs
                ncs = {}
                for i in range(gsz):
                    bp = jax.tree_util.tree_map(lambda t: t[i], gp)
                    lc = gc[f"l{i}"]
                    x, nc = dense_block(bp, x, cfg, cache=lc, pos=lc["len"],
                                        window=cfg.window_pattern[i],
                                        policy=policy,
                                        adapter_tiles=adapter_tiles)
                    ncs[f"l{i}"] = nc
                return x, ncs

            x, nc = jax.lax.scan(gbody, x, (params["groups"], cache["groups"]))
            new_cache["groups"] = nc
        else:
            blk = moe_block if fam == "moe" else dense_block
            if "block0" in params:
                x, nc0 = dense_block(params["block0"], x, cfg,
                                     cache=cache["block0"],
                                     pos=cache["block0"]["len"],
                                     policy=policy,
                                     adapter_tiles=adapter_tiles)
                new_cache["block0"] = nc0

            def body(x, bs):
                bp, lc = bs
                x, nc = blk(bp, x, cfg, cache=lc, pos=lc["len"],
                            policy=policy, adapter_tiles=adapter_tiles)
                return x, nc

            x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = nc
    elif fam == "ssm":
        def body(x, bs):
            bp, st = bs
            x, ns = rwkv6.rwkv_block(bp, x, cfg, state=st, policy=policy)
            return x, ns

        x, ns = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = ns
    elif fam == "hybrid":
        pat = cfg.hybrid.pattern
        gsz = len(pat)

        def gbody(x, gs):
            gp, gc = gs
            nstates = {}
            for i in range(gsz):
                bp, st = gp[f"l{i}"], gc[f"l{i}"]
                if pat[i] == "R":
                    x, ns = griffin.recurrent_block(bp, x, cfg, state=st,
                                                    policy=policy)
                else:
                    x, ns = dense_block(bp, x, cfg, cache=st, pos=st["len"],
                                        window=cfg.hybrid.window,
                                        policy=policy)
                nstates[f"l{i}"] = ns
            return x, nstates

        x, ng = jax.lax.scan(gbody, x, (params["groups"], cache["groups"]))
        new_cache["groups"] = ng
        n_groups = jax.tree_util.tree_leaves(params["groups"])[0].shape[0]
        ntail = []
        for i, (bp, st) in enumerate(zip(params["tail"], cache["tail"])):
            li = n_groups * gsz + i
            if pat[li % gsz] == "R":
                x, ns = griffin.recurrent_block(bp, x, cfg, state=st,
                                                policy=policy)
            else:
                x, ns = dense_block(bp, x, cfg, cache=st, pos=st["len"],
                                    window=cfg.hybrid.window, policy=policy)
            ntail.append(ns)
        new_cache["tail"] = ntail
    elif fam == "audio":
        x = x + _sinusoid_at(cache["blocks"]["len"][0], cfg.d_model, x.dtype)
        enc_out = cache["enc_out"]

        def body(x, bs):
            bp, lc = bs
            h, nc = layers.attention(bp["attn"],
                                     layers.norm(bp["ln1"], x, cfg,
                                                 policy=policy), cfg,
                                     cache=lc, pos=lc["len"], use_rope=False,
                                     policy=policy)
            x = x + h
            h, _ = layers.attention(bp["xattn"],
                                    layers.norm(bp["lnx"], x, cfg,
                                                policy=policy), cfg,
                                    causal=False, kv_x=enc_out, use_rope=False,
                                    policy=policy)
            x = x + h
            x = x + layers.mlp(bp["mlp"],
                               layers.norm(bp["ln2"], x, cfg, policy=policy),
                               cfg, policy=policy)
            return x, nc

        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = nc
    else:
        raise ValueError(fam)

    x = layers.norm(params["final_norm"], x, cfg, policy=policy)
    return layers.unembed(params["embed"], x, cfg), new_cache


def _sinusoid_at(pos, d, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(dtype)


# ---------------------------------------------------------------------------
# trainable-parameter partitioning (LoRA A/B only)
# ---------------------------------------------------------------------------


def trainable_mask(params):
    """Pytree of bools: True for LoRA factors (keys 'a'/'b' under a linear)."""
    def mark(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        return keys[-1] in ("a", "b")

    return jax.tree_util.tree_map_with_path(mark, params)


def split_params(params):
    """(trainable, frozen) — partition by trainable_mask."""
    mask = trainable_mask(params)
    train = jax.tree_util.tree_map(lambda p, m: p if m else None, params, mask)
    frozen = jax.tree_util.tree_map(lambda p, m: None if m else p, params, mask)
    return train, frozen


def merge_params(train, frozen):
    return jax.tree_util.tree_map(
        lambda t, f: t if f is None else f, train, frozen,
        is_leaf=lambda x: x is None)
