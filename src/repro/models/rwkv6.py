"""RWKV6 "Finch" time-mix / channel-mix (attention-free) [arXiv:2404.05892].

The WKV recurrence  S_t = Diag(w_t)·S_{t-1} + k_tᵀ v_t,  y_t = r_t·S_{t-1}
+ (r_t·(u⊙k_t))·v_t  is computed in **chunkwise-parallel** form (intra-chunk
matmuls on the MXU + inter-chunk [H, D, D] state carry), the TPU-idiomatic
formulation — a sequential per-token scan would leave the MXU idle and make
autodiff store O(N) states. Chunks are wrapped in ``jax.checkpoint`` so the
backward recomputes intra-chunk tensors from chunk-boundary states only:
the paper's block-sequential memory discipline applied along *time* instead
of depth (DESIGN.md §5).

Simplification vs the full Finch recipe: token-shift mixing coefficients are
static vectors (no data-dependent ddlerp) — noted in DESIGN.md.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.policy import STRUCTURED, ExecutionPolicy
from repro.configs.base import ArchConfig
from repro.models import layers

WKV_CHUNK = 64


def rwkv_block_params(key, cfg: ArchConfig, *, lora: bool = True):
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    tg = cfg.lora.targets
    dtype = jnp.dtype(cfg.dtype)
    H = cfg.n_heads
    return {
        "ln1": jnp.ones((d,), dtype),
        "tm": {  # time-mix
            "mu": 0.5 * jnp.ones((5, d), dtype),  # r,k,v,g,w shift mixes
            "r": layers.linear_params(ks[0], d, d, cfg, lora=lora and "q" in tg),
            "k": layers.linear_params(ks[1], d, d, cfg, lora=lora and "k" in tg),
            "v": layers.linear_params(ks[2], d, d, cfg, lora=lora and "v" in tg),
            "g": layers.linear_params(ks[3], d, d, cfg, lora=lora and "gate" in tg),
            "w": layers.linear_params(ks[4], d, d, cfg, lora=False),  # decay proj
            "w0": jnp.full((d,), -6.0, dtype),   # decay bias: slow default decay
            "u": jax.random.normal(ks[5], (d,), dtype) * 0.1,  # bonus
            "gn": jnp.ones((d,), dtype),         # per-head group norm weight
            "o": layers.linear_params(ks[6], d, d, cfg, lora=lora and "o" in tg),
        },
        "ln2": jnp.ones((d,), dtype),
        "cm": {  # channel-mix
            "mu": 0.5 * jnp.ones((2, d), dtype),
            "k": layers.linear_params(ks[7], d, cfg.d_ff, cfg, lora=lora and "up" in tg),
            "v": layers.linear_params(ks[8], cfg.d_ff, d, cfg, lora=lora and "down" in tg),
            "r": layers.linear_params(ks[9], d, d, cfg, lora=lora and "gate" in tg),
        },
    }


def _token_shift(x, last: Optional[jax.Array]):
    """x: [B,N,d] -> previous-token tensor. ``last``: [B,d] decode state."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return last[:, None, :]


def wkv_chunked(r, k, v, logw, u, state):
    """Chunkwise-parallel WKV.

    r/k/v/logw: [B, N, H, D] (logw = log decay, negative), u: [H, D],
    state: [B, H, D, D] (key-dim × value-dim). Returns (y, new_state).
    """
    B, N, H, D = r.shape
    C = min(WKV_CHUNK, N)
    pad = (-N) % C
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    T = r.shape[1]
    nc = T // C

    def to_chunks(t):
        return t.reshape(B, nc, C, H, D).transpose(1, 0, 3, 2, 4)  # [nc,B,H,C,D]

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))

    mask = jnp.tril(jnp.ones((C, C), jnp.float32), -1)  # strictly lower: j < i

    @jax.checkpoint
    def chunk(state, inp):
        ri, ki, vi, wi = inp  # [B,H,C,D] each; fp32 inside
        ri, ki, vi = ri.astype(jnp.float32), ki.astype(jnp.float32), vi.astype(jnp.float32)
        wi = wi.astype(jnp.float32)
        b = jnp.cumsum(wi, axis=2)                      # b_i = Σ_{j<=i} logw_j
        q_dec = ri * jnp.exp(b - wi)                    # r_i ⊙ exp(b_{i-1})
        k_dec = ki * jnp.exp(-b)                        # k_j ⊙ exp(-b_j)
        # intra-chunk: A_ij = q_dec_i · k_dec_j for j<i, plus u-bonus diagonal
        A = jnp.einsum("bhid,bhjd->bhij", q_dec, k_dec) * mask
        diag = jnp.einsum("bhid,hd,bhid->bhi", ri, u.astype(jnp.float32), ki)
        y = jnp.einsum("bhij,bhjd->bhid", A, vi) + diag[..., None] * vi
        # inter-chunk: y_i += (r_i ⊙ exp(b_{i-1})) · S
        y = y + jnp.einsum("bhid,bhdv->bhiv", q_dec, state)
        # state' = Diag(exp(b_C)) S + Σ_j (k_j ⊙ exp(b_C − b_j))ᵀ v_j
        bC = b[:, :, -1:, :]
        state = state * jnp.exp(bC.squeeze(2))[..., None] + \
            jnp.einsum("bhjd,bhjv->bhdv", ki * jnp.exp(bC - b), vi)
        return state, y

    # u broadcast per head-dim: reshape [H*D] weight vector to [H, D] outside.
    state, ys = jax.lax.scan(chunk, state.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, D)[:, :N]
    return y, state


def wkv_step(r, k, v, logw, u, state):
    """Single-token recurrence (decode). r/k/v/logw: [B,H,D]; u: [H,D]."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    y = jnp.einsum("bhd,bhdv->bhv", rf, state) + \
        jnp.einsum("bhd,hd->bh", rf * kf,
                   u.astype(jnp.float32))[..., None] * vf
    state = state * jnp.exp(logw.astype(jnp.float32))[..., None] + \
        jnp.einsum("bhd,bhv->bhdv", kf, vf)
    return y, state


def time_mix(p, x, cfg: ArchConfig, *, state=None,
             policy: ExecutionPolicy = STRUCTURED):
    """x: [B,N,d]. state (decode): {"shift": [B,d], "wkv": [B,H,D,D]}."""
    B, N, d = x.shape
    H = cfg.n_heads
    D = cfg.resolved_head_dim
    xx = _token_shift(x, None if state is None else state["shift"])
    mu = p["mu"]
    mix = lambda i: x + (xx - x) * mu[i]
    r = layers.apply_linear(p["r"], mix(0), cfg, policy=policy)
    k = layers.apply_linear(p["k"], mix(1), cfg, policy=policy)
    v = layers.apply_linear(p["v"], mix(2), cfg, policy=policy)
    g = layers.act_silu(layers.apply_linear(p["g"], mix(3), cfg, policy=policy), policy)
    logw = -jnp.exp((layers.apply_linear(p["w"], mix(4), cfg, policy=policy)
                     + p["w0"]).astype(jnp.float32))

    hd = lambda t: t.reshape(B, N, H, D)
    u = p["u"].reshape(H, D)
    if state is None:
        y, _ = wkv_chunked(hd(r), hd(k), hd(v), hd(logw), u,
                           jnp.zeros((B, H, D, D), jnp.float32))
        new_state = None
    else:
        y1, wkv = wkv_step(hd(r)[:, 0], hd(k)[:, 0], hd(v)[:, 0],
                           hd(logw)[:, 0], u, state["wkv"])
        y = y1[:, None].reshape(B, N, H, D)
        new_state = {"shift": x[:, -1], "wkv": wkv}
    # per-head group norm then gate
    yn = layers.norm(jnp.ones((D,), y.dtype), y.astype(x.dtype), cfg, policy=policy)
    yn = (yn.reshape(B, N, d) * p["gn"]) * g
    return layers.apply_linear(p["o"], yn, cfg, policy=policy), new_state


def channel_mix(p, x, cfg: ArchConfig, *, state=None,
                policy: ExecutionPolicy = STRUCTURED):
    xx = _token_shift(x, None if state is None else state)
    mu = p["mu"]
    xk = x + (xx - x) * mu[0]
    xr = x + (xx - x) * mu[1]
    kk = layers.apply_linear(p["k"], xk, cfg, policy=policy)
    kk = jnp.square(jax.nn.relu(kk))
    vv = layers.apply_linear(p["v"], kk, cfg, policy=policy)
    rr = jax.nn.sigmoid(layers.apply_linear(p["r"], xr, cfg, policy=policy))
    new_state = None if state is None else x[:, -1]
    return rr * vv, new_state


def rwkv_block(p, x, cfg: ArchConfig, *, state=None,
               policy: ExecutionPolicy = STRUCTURED):
    """Returns (x_out, new_state). state: {"shift_tm","wkv","shift_cm"}."""
    tm_state = None if state is None else {"shift": state["shift_tm"],
                                           "wkv": state["wkv"]}
    h, tm_new = time_mix(p["tm"], layers.norm(p["ln1"], x, cfg, policy=policy),
                         cfg, state=tm_state, policy=policy)
    x = x + h
    h, cm_new = channel_mix(p["cm"], layers.norm(p["ln2"], x, cfg, policy=policy),
                            cfg, state=None if state is None else state["shift_cm"],
                            policy=policy)
    x = x + h
    new_state = None
    if state is not None:
        new_state = {"shift_tm": tm_new["shift"], "wkv": tm_new["wkv"],
                     "shift_cm": cm_new}
    return x, new_state


def make_rwkv_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    H, D = cfg.n_heads, cfg.resolved_head_dim
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, D, D), jnp.float32),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
    }
