"""Static HLO cost analyzer with while-loop trip-count scaling.

XLA's built-in ``cost_analysis()`` counts a ``while`` body **once**, which
under-reports scan-over-layers models by ~L×. This analyzer walks the
compiled per-device HLO text, computes per-computation

    * dot FLOPs              (2 · |result| · |contracted dims|)
    * bytes accessed         (operand reads + result writes of every
                              materializing top-level op — XLA convention)
    * collective payloads    (per kind; max(result, operands) of the op)

and scales callee contributions through the call graph:
``while`` × known_trip_count (from backend_config, falling back to the
condition constant), ``fusion``/``call`` × 1, ``conditional`` → max branch.

Totals are per-device (the module is the SPMD-partitioned program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that neither read nor write HBM on their own. Standalone ``convert``
# ops are excluded too: XLA:CPU materializes bf16<->f32 shims around every
# dot (no native bf16 matmul); on the TPU target the MXU consumes bf16
# directly and residual converts fuse into their consumers.
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "while", "call", "conditional", "partition-id",
    "replica-id", "rng-get-and-update-state", "get-dimension-size",
    "convert",
}


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    result_text: str
    opcode: str
    rest: str  # operands + attributes

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.result_text)


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self.result_of: Dict[str, str] = {}  # op name -> result type text
        self._parse(text)
        self._totals_cache: Dict[str, Totals] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                s = line.strip()
                if s.endswith("{") and "->" in s and "=" not in s.split("(")[0]:
                    is_entry = s.startswith("ENTRY")
                    name = s.split()[1 if is_entry else 0]
                    cur = name.lstrip("%")
                    if is_entry:
                        self.entry = cur
                    self.computations[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, result, opcode, rest = m.groups()
            op = Op(name, result, opcode, rest)
            self.computations[cur].append(op)
            self.result_of[name] = result

    # --------------------------------------------------------------- helpers
    def _operand_bytes(self, op: Op) -> int:
        """Bytes of named operands (resolved through the symbol table)."""
        total = 0
        # operand list = rest up to the matching close paren (approx: first
        # '),' or end); operands are %refs or inline typed literals
        depth, end = 1, len(op.rest)
        for i, ch in enumerate(op.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = op.rest[:end]
        for ref in re.finditer(r"%([\w.\-]+)", operand_text):
            r = self.result_of.get(ref.group(1))
            if r is not None:
                total += _shapes_bytes(r)
        total += _shapes_bytes(re.sub(r"%[\w.\-]+", "", operand_text))
        return total

    def _dot_flops(self, op: Op) -> float:
        res = _shape_dims(op.result_text)
        if not res:
            return 0.0
        out_elems = 1
        for d in res[0][1]:
            out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        contract = 1
        if m:
            dims = [int(x) for x in m.group(1).split(",")] if m.group(1) else []
            lhs_ref = re.search(r"%([\w.\-]+)", op.rest)
            if lhs_ref:
                lhs_type = self.result_of.get(lhs_ref.group(1), "")
                lhs_dims = _shape_dims(lhs_type)
                if lhs_dims:
                    for d in dims:
                        if d < len(lhs_dims[0][1]):
                            contract *= lhs_dims[0][1][d]
        return 2.0 * out_elems * contract

    def _trip_count(self, op: Op) -> float:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
        if m:
            return float(m.group(1))
        # fallback: largest integer constant in the condition computation
        m = re.search(r"condition=%?([\w.\-]+)", op.rest)
        if m and m.group(1) in self.computations:
            consts = []
            for cop in self.computations[m.group(1)]:
                if cop.opcode == "constant":
                    c = re.search(r"constant\((\d+)\)", "constant(" + cop.rest)
                    if c:
                        consts.append(int(c.group(1)))
            if consts:
                return float(max(consts))
        return 1.0

    def _callee(self, op: Op, attr: str) -> Optional[str]:
        m = re.search(attr + r"=%?([\w.\-]+)", op.rest)
        return m.group(1) if m else None

    def _update_operand_bytes(self, op: Op) -> int:
        """dynamic-update-slice: bytes of the update (2nd) operand."""
        refs = re.findall(r"%([\w.\-]+)", op.rest)
        if len(refs) >= 2:
            r = self.result_of.get(refs[1])
            if r is not None:
                return _shapes_bytes(r)
        return op.result_bytes

    def _fusion_kind(self, op: Op) -> str:
        """Classify a fusion for traffic accounting.

        'dus'     — callee root performs a dynamic-update-slice: in-place on
                    hardware; traffic = 2× the non-buffer operands.
        'convert' — callee is a pure dtype cast chain: a CPU-backend artifact
                    (XLA:CPU upcasts bf16 dots to f32). The TPU MXU consumes
                    bf16 natively → zero HBM traffic on the target.
        'real'    — ordinary fusion.
        """
        callee = self._callee(op, "calls")
        ops = self.computations.get(callee or "", [])
        if any(o.opcode == "dynamic-update-slice" for o in ops):
            return "dus"
        # dtype/layout shims XLA:CPU inserts around bf16 dots; the TPU MXU
        # consumes bf16 directly and folds transposes into the dot
        trivial = {"convert", "bitcast", "parameter", "get-tuple-element",
                   "tuple", "constant", "copy", "transpose", "reshape",
                   "broadcast"}
        if ops and all(o.opcode in trivial for o in ops):
            return "convert"
        if any(o.opcode == "dynamic-slice" for o in ops):
            return "ds"
        return "real"

    def _fusion_bytes(self, op: Op) -> int:
        kind = self._fusion_kind(op)
        if kind == "convert":
            return 0
        if kind == "dus":
            res = op.result_bytes
            refs = re.findall(r"%([\w.\-]+)", op.rest)
            small = 0
            for ref in refs:
                r = self.result_of.get(ref)
                if r is None:
                    continue
                b = _shapes_bytes(r)
                if b < res:  # exclude the aliased full buffer operand(s)
                    small += b
            return 2 * small
        if kind == "ds":
            # gathers a slice out of a large buffer: read region + write
            return 2 * op.result_bytes
        return op.result_bytes + self._operand_bytes(op)

    # ---------------------------------------------------------------- totals
    def totals(self, comp: Optional[str] = None) -> Totals:
        comp = comp or self.entry
        if comp in self._totals_cache:
            return self._totals_cache[comp]
        t = Totals()
        self._totals_cache[comp] = t  # cycle guard
        for op in self.computations.get(comp, []):
            oc = op.opcode
            base = oc.replace("-start", "")
            if base in _COLLECTIVES:
                payload = max(op.result_bytes, self._operand_bytes(op))
                t.coll[base] = t.coll.get(base, 0.0) + payload
                t.bytes += op.result_bytes + self._operand_bytes(op)
                continue
            if oc == "while":
                trip = self._trip_count(op)
                body = self._callee(op, "body")
                cond = self._callee(op, "condition")
                if body:
                    t.add(self.totals(body), trip)
                if cond:
                    t.add(self.totals(cond), trip)
                continue
            if oc in ("fusion", "call", "async-start"):
                callee = self._callee(op, "calls")
                if callee:
                    inner = self.totals(callee)
                    t.flops += inner.flops          # dots inside fusions
                    t.add(Totals(coll=dict(inner.coll)))
                t.bytes += (self._fusion_bytes(op) if oc == "fusion"
                            else op.result_bytes + self._operand_bytes(op))
                continue
            if oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.rest)
                names = []
                if branches:
                    names = [b.strip().lstrip("%") for b in
                             branches[0].split(",")]
                else:
                    for attr in ("true_computation", "false_computation"):
                        c = self._callee(op, attr)
                        if c:
                            names.append(c)
                if names:
                    best = max((self.totals(n) for n in names),
                               key=lambda x: x.flops + x.bytes)
                    t.add(best)
                t.bytes += op.result_bytes
                continue
            if oc in ("dot", "dot_general"):
                t.flops += self._dot_flops(op)
                t.bytes += op.result_bytes + self._operand_bytes(op)
                continue
            if oc == "convolution":
                # rare here; approximate as result × 2 × kernel-elems skipped
                t.bytes += op.result_bytes + self._operand_bytes(op)
                continue
            if oc in _FREE_OPS:
                continue
            if oc == "dynamic-update-slice":
                # in-place on hardware: read update + write region (the big
                # buffer operand is NOT streamed)
                t.bytes += 2 * self._update_operand_bytes(op)
                continue
            if oc == "dynamic-slice":
                t.bytes += 2 * op.result_bytes  # read region + write result
                continue
            # generic materializing op (fused elsewhere ops don't appear here)
            t.bytes += op.result_bytes + self._operand_bytes(op)
        self._totals_cache[comp] = t
        return t


def analyze_text(text: str) -> Totals:
    return HloModule(text).totals()


# ---------------------------------------------------------------------------
# diagnostics: attribute costs to individual ops (with while-trip scaling)
# ---------------------------------------------------------------------------


def top_ops(text: str, kind: str = "collective", n: int = 12):
    """Top-n cost contributors. kind: 'collective' | 'flops' | 'bytes'.

    Returns [(scaled_cost, opcode, result_type, computation, trips)].
    """
    mod = HloModule(text)

    # multiplier per computation: product of trip counts on the call path
    mult = {c: 0.0 for c in mod.computations}

    def walk(comp, m):
        mult[comp] = mult.get(comp, 0.0) + m
        for op in mod.computations.get(comp, []):
            if op.opcode == "while":
                trip = mod._trip_count(op)
                for attr in ("body", "condition"):
                    c = mod._callee(op, attr)
                    if c:
                        walk(c, m * trip)
            elif op.opcode in ("fusion", "call", "async-start"):
                c = mod._callee(op, "calls")
                if c:
                    walk(c, m)
            elif op.opcode == "conditional":
                for cname in re.findall(r"%([\w.\-]+)", op.rest):
                    if cname in mod.computations:
                        walk(cname, m)

    walk(mod.entry, 1.0)

    rows = []
    for comp, ops in mod.computations.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        for op in ops:
            base = op.opcode.replace("-start", "")
            if kind == "collective" and base in _COLLECTIVES:
                cost = max(op.result_bytes, mod._operand_bytes(op))
            elif kind == "flops" and op.opcode in ("dot", "dot_general"):
                cost = mod._dot_flops(op)
            elif kind == "bytes" and op.opcode not in _FREE_OPS:
                if op.opcode == "dynamic-update-slice":
                    cost = 2 * mod._update_operand_bytes(op)
                elif op.opcode == "dynamic-slice":
                    cost = 2 * op.result_bytes
                elif op.opcode == "fusion":
                    cost = mod._fusion_bytes(op)
                else:
                    cost = op.result_bytes + mod._operand_bytes(op)
                if cost == 0:
                    continue
            else:
                continue
            rows.append((cost * m, op.opcode, op.result_text[:60], comp, m))
    rows.sort(reverse=True)
    return rows[:n]
