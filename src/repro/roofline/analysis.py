"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` provides FLOPs / bytes-accessed; collective bytes are
parsed from the compiled HLO text (``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute``), taking the
largest shape token on each collective line (the payload side: AG output,
RS input, AR either).

Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

HW = {
    "peak_flops": 197e12,   # bf16 / chip
    "hbm_bw": 819e9,        # bytes/s / chip
    "ici_bw": 50e9,         # bytes/s / link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dtype, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total payload bytes per collective kind in an HLO module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op lines look like:  %name = TYPE all-gather(...), ...
        for kind in _COLLECTIVES:
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                sizes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(stripped)]
                if sizes:
                    out[kind] += max(sizes)
                break
    return out


def predicted_grad_sync_bytes(n_trainable: int, mesh_axes: Dict[str, int],
                              dtype_bytes: int = 4) -> int:
    """Analytic lower bound on the per-device data-parallel gradient-sync
    payload of one train step, for checking compiled HLO (via
    :func:`collective_bytes`) against the roofline model — the emulated-fleet
    suite (tests/multihost/) asserts measured >= predicted.

    Every trainable element is reduced over the DP axes exactly once per
    step, and a device holds at least ``1/model`` of the elements (model-
    sharded LoRA factors), so::

        bytes >= n_trainable * dtype_bytes / model    (when dp > 1)

    With a single data shard there is nothing to sync (0).

    Caller picks what to count: when checking *static* HLO text, pass the
    per-loop-body element count (one layer slice of leaves that live under
    a scanned block stack — the compiled program contains that body once
    however many times it runs) in the gradient's *compute* dtype.
    """
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh_axes.get(a, 1)
    if dp <= 1:
        return 0
    return (n_trainable * dtype_bytes) // max(mesh_axes.get("model", 1), 1)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = processed tokens.

    For decode shapes D = global_batch (one token per sequence)."""
    n_params = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params * tokens  # forward only
    return 2.0 * n_params * shape.global_batch  # decode: 1 token/seq


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops_: float = 0.0
    mem_per_device: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * HW["peak_flops"])

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HW["hbm_bw"])

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * HW["ici_bw"])

    @property
    def dominant(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_ / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent at the limiting roofline doing useful
        work: MODEL_FLOPS-time / max(term)."""
        tmax = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = self.model_flops_ / (self.chips * HW["peak_flops"])
        return t_useful / tmax if tmax else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops_, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(cfg, shape, mesh_name: str, chips: int, compiled,
            hlo_text: Optional[str] = None) -> RooflineReport:
    """Terms are PER-DEVICE (the compiled module is the SPMD-partitioned
    program), matching  total/(chips×peak)  in the brief's formulas.

    FLOPs/bytes come from the trip-count-aware HLO analyzer
    (``hlo_parse``), because XLA's ``cost_analysis()`` counts scan bodies
    once (~L× under-report for scan-over-layers models) — both are recorded.
    """
    from repro.roofline.hlo_parse import analyze_text

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    tot = analyze_text(text)
    flops = tot.flops * chips            # whole-job totals; terms divide back
    nbytes = tot.bytes * chips
    coll = {k: v * chips for k, v in tot.coll.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            }
    except Exception:
        pass
    rep = RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll, model_flops_=model_flops(cfg, shape),
        mem_per_device=mem)
    rep.xla_cost_analysis = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    return rep
