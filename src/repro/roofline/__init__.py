from repro.roofline.analysis import (  # noqa: F401
    HW, RooflineReport, analyze, collective_bytes, model_flops,
)
