"""Pluggable gradient-engine registry.

An *engine* is one way of producing (loss, LoRA-grads) — or directly a
parameter update — over the shared model stack: MeSP's structured backward,
its Pallas-kernel form, the paper's §4.3 sequential loop, the MeBP autodiff
baseline, the store-h ablation, MeZO's zeroth-order estimate, ...

Each registration declares everything the rest of the system needs to offer
the engine as a scenario:

* ``build_step``     — step-builder used by the :class:`~repro.api.trainer.
  Trainer` facade and ``launch/train.py``;
* ``value_and_grad`` — uniform gradient hook used by ``benchmarks/memory.py``
  (AOT memory measurement) and the gradient-quality tooling;
* ``quantize``       — supported ``--quantize`` methods (validated by
  TrainSpec/Trainer before any compute);
* ``memsim``         — which analytical memory model in ``benchmarks/memsim``
  describes the engine's retention behaviour;
* ``benchmark``      — whether the benchmark harness sweeps it.

Registering a new engine requires **zero edits** to ``launch/train.py``,
``benchmarks/run.py`` or ``models/*``: CLI ``--engine`` choices, the
benchmark ENGINES list and the README engine-matrix check are all generated
from this registry (see docs/api.md for a walkthrough).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple


class UnknownEngineError(KeyError):
    """Raised by :func:`get_engine` for a name with no registration."""


@dataclasses.dataclass(frozen=True)
class Engine:
    """One registered gradient engine (see module docstring)."""
    name: str
    description: str
    #: model-stack backend (an ExecutionPolicy.backend value) for engines
    #: that differentiate through the model; None for engines with a custom
    #: regime (e.g. mezo runs two plain forwards)
    backend: Optional[str]
    #: supported frozen-W0 formats (subset of core.quant.METHODS)
    quantize: Tuple[str, ...]
    #: analytical memory model in benchmarks/memsim describing this engine
    memsim: str
    #: (spec, cfg, opt, policy) -> step(params, opt_state, batch)
    #:                                -> (params, opt_state, loss)
    build_step: Callable
    #: (params, cfg, batch, *, policy, key=None) -> (loss, grads-over-LoRA)
    value_and_grad: Optional[Callable] = None
    #: swept by benchmarks/run.py tables when True
    benchmark: bool = True
    #: paper section the engine reproduces (docs / README matrix)
    paper: str = ""


_REGISTRY: dict = {}
_BUILTINS_LOADED = False


def _ensure_builtins():
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        from repro.api import engines as _  # noqa: F401  (self-registers)
        # only after a successful import: a failed one must surface its
        # error on every call, not leave an empty registry behind
        _BUILTINS_LOADED = True


def register_engine(name: str, *, description: str, backend: Optional[str],
                    quantize: Tuple[str, ...] = ("none", "int8", "int4",
                                                 "nf4"),
                    memsim: str = "mesp", value_and_grad=None,
                    benchmark: bool = True, paper: str = ""):
    """Decorator over the engine's step-builder.

    ``@register_engine("my_engine", backend="structured", ...)`` on a
    function ``(spec, cfg, opt, policy) -> step`` registers the engine; the
    decorated builder is returned unchanged.
    """
    def deco(build_step):
        if name in _REGISTRY:
            raise ValueError(f"engine {name!r} is already registered")
        _REGISTRY[name] = Engine(
            name=name, description=description, backend=backend,
            quantize=tuple(quantize), memsim=memsim, build_step=build_step,
            value_and_grad=value_and_grad, benchmark=benchmark, paper=paper)
        return build_step

    return deco


def unregister_engine(name: str) -> None:
    """Remove a registration (test hook — builtin engines should stay)."""
    _REGISTRY.pop(name, None)


def get_engine(name: str) -> Engine:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownEngineError(
            f"unknown engine {name!r}; registered engines: "
            f"{sorted(_REGISTRY)}") from None


def list_engines() -> Tuple[Engine, ...]:
    """All registrations, in registration order (= CLI choices order)."""
    _ensure_builtins()
    return tuple(_REGISTRY.values())


def engine_names() -> Tuple[str, ...]:
    return tuple(e.name for e in list_engines())
