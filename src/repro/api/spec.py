"""TrainSpec: one frozen, declarative description of a training run.

A TrainSpec carries everything ``launch/train.py`` used to thread as loose
argparse values: architecture, engine, quantize method, optimizer/lr,
seq/batch, seed, checkpoint cadence, plus sharding (``act_spec``) and kernel
overrides.  It round-trips through the CLI (``to_cli_args`` /
``from_cli_args``), so a spec is also a reproducible command line.

The launcher's argument parser is *generated* here: ``--engine`` choices
come from the engine registry and ``--quantize`` choices from
``core.quant.METHODS`` — registering a new engine makes it a CLI choice with
no launcher edits.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Optional, Tuple

from repro.api.policy import ExecutionPolicy
from repro.api.registry import get_engine, list_engines

OPTIMIZERS = ("sgd", "sgd_momentum", "adamw")

#: sentinel metadata marking fields that do not round-trip through the CLI
_NO_CLI = {"cli": False}


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    arch: str = "qwen2.5-0.5b"
    reduced: bool = False
    engine: str = "mesp"
    quantize: str = "none"
    optimizer: str = "sgd"
    lr: float = 1e-4
    steps: int = 100
    batch: int = 1          # paper: batch 1
    seq: int = 256          # paper: seq 256
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_interval: int = 50
    log_interval: int = 10
    # --- kernel / execution overrides (ExecutionPolicy fields) -------------
    flash_min_seq: int = 1024
    flash_chunk: int = 1024
    pallas_interpret: Optional[bool] = None   # None = auto (off-TPU only)
    fuse_rope: bool = False                   # pallas: RoPE inside the flash kernels
    # --- resilience: chaos injection, degradation ladder, step guard -------
    inject_faults: str = ""        # FaultPlan string ("" = no injection)
    degrade: str = "on"            # memory-pressure ladder on OOM (on/off)
    guard: str = "on"              # NaN/spike step guard (on/off)
    guard_budget: int = 8          # anomalous steps rejected before aborting
    max_retries: int = 3           # consecutive step failures before raising
    straggler_factor: float = 10.0  # watchdog: slow = factor x EWMA step time
    straggler_limit: int = 3       # consecutive slow steps before restart
    # --- telemetry: structured metrics / events / spans (docs/telemetry.md)
    telemetry: str = "off"         # typed JSONL events + metrics + spans
    telemetry_dir: str = ""        # output dir ("" = <ckpt_dir>/telemetry)
    profile: str = "off"           # jax.profiler capture around the run
    mem_budget_mb: float = 0.0     # watermark-pressure degrade limit (0=off)
    quiet: bool = False            # console: warnings only
    # --- sharding: (data, model) mesh over the visible devices ------------
    model_parallel: int = 1        # model-axis size; data axis = devices/mp
    # --- sharding: not CLI-serializable (PartitionSpec objects); set
    # programmatically by the distributed launchers ------------------------
    act_spec: Any = dataclasses.field(default=None, metadata=_NO_CLI)

    # ------------------------------------------------------------------ API
    def validate(self) -> "TrainSpec":
        """Check engine/quantize/optimizer coherence against the registry.
        Returns self so it chains; raises UnknownEngineError/ValueError."""
        eng = get_engine(self.engine)
        if self.quantize not in eng.quantize:
            raise ValueError(
                f"engine {self.engine!r} does not support "
                f"--quantize {self.quantize!r} (supported: {eng.quantize})")
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                             f"expected one of {OPTIMIZERS}")
        for name in ("degrade", "guard", "telemetry", "profile"):
            if getattr(self, name) not in ("on", "off"):
                raise ValueError(f"--{name} must be 'on' or 'off', "
                                 f"got {getattr(self, name)!r}")
        if self.mem_budget_mb < 0:
            raise ValueError(f"--mem-budget-mb must be >= 0, "
                             f"got {self.mem_budget_mb}")
        if self.model_parallel < 1:
            raise ValueError(f"--model-parallel must be >= 1, "
                             f"got {self.model_parallel}")
        if self.inject_faults:
            from repro.runtime.faults import FaultPlan
            # parse errors (unknown kind, bad syntax) surface before compute
            FaultPlan.from_string(self.inject_faults,
                                  total_steps=self.steps, seed=self.seed)
        return self

    def policy(self) -> ExecutionPolicy:
        """The ExecutionPolicy this spec's engine threads through the model
        stack (engines with a custom regime, e.g. mezo, get ``plain``)."""
        eng = get_engine(self.engine)
        return ExecutionPolicy(
            backend=eng.backend or "plain", quantize=self.quantize,
            act_spec=self.act_spec, flash_min_seq=self.flash_min_seq,
            flash_chunk=self.flash_chunk, interpret=self.pallas_interpret,
            fuse_rope=self.fuse_rope)

    # ------------------------------------------------------- CLI round trip
    def to_cli_args(self) -> list:
        """Minimal argv reproducing this spec (non-default fields only).
        ``act_spec`` is programmatic-only and never serialized."""
        argv = []
        for f in dataclasses.fields(self):
            if not f.metadata.get("cli", True):
                continue
            val = getattr(self, f.name)
            if val == f.default:
                continue
            flag = "--" + f.name.replace("_", "-")
            if f.name in ("reduced", "fuse_rope", "quiet"):
                argv.append(flag)
            elif f.name == "pallas_interpret":
                argv += [flag, {True: "on", False: "off", None: "auto"}[val]]
            else:
                argv += [flag, repr(val) if isinstance(val, float) else
                         str(val)]
        return argv

    @classmethod
    def from_cli_args(cls, argv=None) -> "TrainSpec":
        return cls.from_namespace(build_arg_parser().parse_args(argv))

    @classmethod
    def from_namespace(cls, ns) -> "TrainSpec":
        """Spec from a parsed :func:`build_arg_parser` namespace. Extra
        attributes are ignored — launchers with their own flags (e.g.
        ``launch/serve.py``'s ``--max-len``) extend the generated parser and
        still get a spec from the shared fields."""
        kw = {f.name: getattr(ns, f.name) for f in dataclasses.fields(cls)
              if f.metadata.get("cli", True)}
        kw["pallas_interpret"] = {"on": True, "off": False,
                                  "auto": None}[kw["pallas_interpret"]]
        return cls(**kw)


def build_arg_parser() -> argparse.ArgumentParser:
    """The training launcher's CLI, generated from the registry (importable:
    scripts/check_readme_flags.py keeps README.md honest against it)."""
    from repro.core.quant import METHODS as QUANT_METHODS

    d = TrainSpec()
    engines = list_engines()
    ap = argparse.ArgumentParser(prog="repro.launch.train")
    ap.add_argument("--arch", default=d.arch)
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny same-family config (CPU-runnable)")
    ap.add_argument("--engine", default=d.engine,
                    choices=[e.name for e in engines],
                    help="gradient engine (registry-generated): " +
                         "; ".join(f"{e.name} = {e.description}"
                                   for e in engines))
    ap.add_argument("--quantize", default=d.quantize,
                    choices=list(QUANT_METHODS),
                    help="frozen-base-weight format; per-engine support is "
                         "declared in the registry and validated up front")
    ap.add_argument("--optimizer", default=d.optimizer,
                    choices=list(OPTIMIZERS))
    ap.add_argument("--lr", type=float, default=d.lr)
    ap.add_argument("--steps", type=int, default=d.steps)
    ap.add_argument("--batch", type=int, default=d.batch)
    ap.add_argument("--seq", type=int, default=d.seq)
    ap.add_argument("--seed", type=int, default=d.seed)
    ap.add_argument("--ckpt-dir", default=d.ckpt_dir)
    ap.add_argument("--ckpt-interval", type=int, default=d.ckpt_interval)
    ap.add_argument("--log-interval", type=int, default=d.log_interval)
    ap.add_argument("--flash-min-seq", type=int, default=d.flash_min_seq,
                    help="structured backend: min seq for the chunked "
                         "flash path")
    ap.add_argument("--flash-chunk", type=int, default=d.flash_chunk)
    ap.add_argument("--pallas-interpret", default="auto",
                    choices=["auto", "on", "off"],
                    help="force the Pallas interpreter (auto = off-TPU only)")
    ap.add_argument("--fuse-rope", action="store_true",
                    help="pallas backend: apply RoPE inside the flash "
                         "kernels (q/k rotated in VMEM, no HBM round-trip)")
    ap.add_argument("--inject-faults", default=d.inject_faults,
                    help="chaos run: deterministic fault plan, e.g. "
                         "'oom@4,corrupt@9,crash@9,nan@14,stall@18:1.5' or "
                         "'random:5' (seeded from --seed); see "
                         "docs/resilience.md")
    ap.add_argument("--degrade", default=d.degrade, choices=["on", "off"],
                    help="on OOM, walk the memory-pressure degradation "
                         "ladder (halve batch -> leaner engine -> int8 W0 "
                         "-> packed int4 W0 -> truncate seq) instead of "
                         "retrying the same program")
    ap.add_argument("--guard", default=d.guard, choices=["on", "off"],
                    help="reject (skip-and-rewind) steps with NaN/Inf loss "
                         "or update-norm spikes")
    ap.add_argument("--guard-budget", type=int, default=d.guard_budget,
                    help="anomalous steps the guard may reject before the "
                         "run aborts")
    ap.add_argument("--max-retries", type=int, default=d.max_retries,
                    help="consecutive step failures tolerated (budget "
                         "resets after every successful step)")
    ap.add_argument("--straggler-factor", type=float,
                    default=d.straggler_factor,
                    help="watchdog: a step slower than factor x the EWMA "
                         "step time is flagged slow")
    ap.add_argument("--straggler-limit", type=int, default=d.straggler_limit,
                    help="consecutive slow steps before a supervised "
                         "restart from checkpoint")
    ap.add_argument("--telemetry", default=d.telemetry,
                    choices=["on", "off"],
                    help="structured observability: typed JSONL events, "
                         "metric registry, trace spans and memory "
                         "watermarks (zero-cost when off); see "
                         "docs/telemetry.md")
    ap.add_argument("--telemetry-dir", default=d.telemetry_dir,
                    help="where JSONL event shards and the Chrome trace "
                         "land (default: <ckpt-dir>/telemetry)")
    ap.add_argument("--profile", default=d.profile, choices=["on", "off"],
                    help="capture a jax.profiler trace for the run under "
                         "<telemetry-dir>/profile (requires --telemetry on)")
    ap.add_argument("--mem-budget-mb", type=float, default=d.mem_budget_mb,
                    help="device memory budget: when the measured watermark "
                         "stays above 90%% of this, the degradation ladder "
                         "walks proactively instead of waiting for an OOM "
                         "(0 = exception-triggered only)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-step and summary console logging "
                         "(structured telemetry sinks are unaffected)")
    ap.add_argument("--model-parallel", type=int, default=d.model_parallel,
                    help="model-axis size of the (data, model) device mesh; "
                         "the data axis takes the remaining devices. With "
                         "one visible device (and 1, the default) training "
                         "is unsharded; see docs/sharding.md")
    return ap
