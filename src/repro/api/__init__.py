"""repro.api: declarative TrainSpec + ExecutionPolicy + engine registry.

Public surface (see docs/api.md):

* :class:`~repro.api.spec.TrainSpec` — frozen description of a training run,
  CLI round-trippable (``to_cli_args``/``from_cli_args``).
* :class:`~repro.api.policy.ExecutionPolicy` — the single execution-regime
  object threaded through ``core``/``models``/``kernels`` (backend,
  quantize, act_spec, flash thresholds, remat, interpret).
* :func:`~repro.api.registry.register_engine` / ``get_engine`` /
  ``list_engines`` — the pluggable gradient-engine registry.
* :class:`~repro.api.trainer.Trainer` — ``Trainer.from_spec(spec).fit()``.

Exports resolve lazily (PEP 562) so that low-level modules can import
``repro.api.policy`` without pulling the trainer stack (which itself imports
the model stack) into their import graph.
"""
from __future__ import annotations

_EXPORTS = {
    "ExecutionPolicy": "policy", "BACKENDS": "policy",
    "STRUCTURED": "policy", "PALLAS": "policy", "PLAIN": "policy",
    "STORE_H": "policy",
    "Engine": "registry", "UnknownEngineError": "registry",
    "register_engine": "registry", "unregister_engine": "registry",
    "get_engine": "registry", "list_engines": "registry",
    "engine_names": "registry",
    "TrainSpec": "spec", "build_arg_parser": "spec", "OPTIMIZERS": "spec",
    "Trainer": "trainer", "TrainResult": "trainer",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.api.{module}"), name)


def __dir__():
    return __all__
