"""Trainer facade: ``Trainer.from_spec(spec).fit(steps)``.

Wraps everything a training run needs around a TrainSpec: config resolution,
engine lookup + validation, optimizer, restartable data pipeline, atomic
checkpointing and the fault-tolerant step driver
(``runtime.fault_tolerance.run_resilient``).  ``launch/train.py``,
``examples/finetune_e2e.py`` and the smoke CI all run through this facade.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, List, Optional

import jax

from repro.api.registry import Engine, get_engine
from repro.api.spec import TrainSpec

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: List  # of runtime.fault_tolerance.StepResult

    @property
    def final_loss(self) -> float:
        return self.history[-1].loss if self.history else float("nan")


class Trainer:
    """One training run, fully described by a TrainSpec.

    ``cfg`` overrides the spec's ``arch``/``reduced`` resolution with an
    explicit ArchConfig (used by examples that build custom configs).
    """

    def __init__(self, spec: TrainSpec, *, cfg=None):
        from repro.configs import get_config
        from repro.optim import make_optimizer
        from repro.optim.schedules import constant

        self.spec = spec.validate()
        self.engine: Engine = get_engine(spec.engine)
        if cfg is None:
            cfg = get_config(spec.arch)
            if spec.reduced:
                cfg = cfg.reduced()
        self.cfg = cfg
        self.policy = spec.policy()
        self.opt = make_optimizer(spec.optimizer, constant(spec.lr))
        self.step_fn = jax.jit(
            self.engine.build_step(spec, cfg, self.opt, self.policy))

    @classmethod
    def from_spec(cls, spec: TrainSpec, *, cfg=None) -> "Trainer":
        return cls(spec, cfg=cfg)

    # ---------------------------------------------------------------- state
    def init_state(self):
        from repro.models import model as model_lib

        params = model_lib.init_params(
            jax.random.PRNGKey(self.spec.seed), self.cfg,
            quantize=self.spec.quantize)
        return params, self.opt.init(params)

    def make_data(self):
        from repro.data import make_batch_iterator

        return make_batch_iterator(
            self.cfg.vocab, self.spec.seq, self.spec.batch,
            host_index=jax.process_index(), host_count=jax.process_count(),
            seed=self.spec.seed)

    # ------------------------------------------------------------------ fit
    def fit(self, steps: Optional[int] = None, *,
            data=None, on_step: Optional[Callable] = None,
            straggler=None) -> TrainResult:
        """Run ``steps`` (default: spec.steps) resilient training steps,
        resuming from the latest checkpoint in ``spec.ckpt_dir`` if any."""
        from repro.checkpoint import Checkpointer
        from repro.runtime.fault_tolerance import StragglerPolicy, \
            run_resilient

        spec = self.spec
        total = steps if steps is not None else spec.steps
        it = data if data is not None else self.make_data()
        ckpt = Checkpointer(spec.ckpt_dir, interval=spec.ckpt_interval)

        def _log_step(res):
            if res.step % spec.log_interval == 0:
                log.info("step %5d  loss %.4f  %.3fs/step",
                         res.step, res.loss, res.seconds)
            if on_step:
                on_step(res)

        params, opt_state, history = run_resilient(
            self.step_fn, self.init_state, it, ckpt, total,
            straggler=straggler or StragglerPolicy(factor=10.0),
            on_step=_log_step)
        return TrainResult(params=params, opt_state=opt_state,
                           history=history)
