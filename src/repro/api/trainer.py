"""Trainer facade: ``Trainer.from_spec(spec).fit(steps)``.

Wraps everything a training run needs around a TrainSpec: config resolution,
engine lookup + validation, optimizer, restartable data pipeline, atomic
checkpointing and the supervised resilient step driver
(``runtime.fault_tolerance.ResilientLoop``) with the full chaos stack —
deterministic fault injection (``--inject-faults``), the memory-pressure
degradation ladder (``runtime/degrade.py``) and the anomaly step guard
(``runtime/guard.py``). ``launch/train.py``, ``examples/finetune_e2e.py``
and the smoke CI all run through this facade.

The trainer is *re-specable* mid-run: every checkpoint manifest records the
spec that produced it, so a restore after a crash reconstitutes the exact
(possibly degraded) program, and an OOM walks the ladder to a cheaper spec
while carrying the optimizer state across compatible transitions.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, List, Optional

import jax

from repro.api.registry import Engine, get_engine
from repro.api.spec import TrainSpec

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: List  # of runtime.fault_tolerance.StepResult
    #: runtime.fault_tolerance.FaultCounters — per-fault accounting for the
    #: run (retries, OOMs, degradations, guard skips, restarts, quarantines)
    counters: Any = None
    #: the TrainSpec the run *ended* on (differs from the requested spec
    #: when the degradation ladder stepped down under memory pressure)
    final_spec: Optional[TrainSpec] = None
    #: ladder rungs applied, in order (e.g. ["halve_batch", "quantize_int8"])
    degradations: List[str] = dataclasses.field(default_factory=list)
    #: telemetry snapshot: guard state always (when guarded); with
    #: ``--telemetry on`` also the metric registry, events-by-kind, span
    #: totals and the measured-vs-memsim watermark comparison
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def final_loss(self) -> float:
        return self.history[-1].loss if self.history else float("nan")

    @property
    def fault_counts(self) -> dict:
        return self.counters.to_dict() if self.counters is not None else {}


#: TrainSpec fields recorded into checkpoint manifests (JSON-safe subset —
#: everything that round-trips through the CLI)
_SPEC_FIELDS = tuple(f.name for f in dataclasses.fields(TrainSpec)
                     if f.metadata.get("cli", True))


def _spec_manifest(spec: TrainSpec) -> dict:
    return {name: getattr(spec, name) for name in _SPEC_FIELDS}


class Trainer:
    """One training run, fully described by a TrainSpec.

    ``cfg`` overrides the spec's ``arch``/``reduced`` resolution with an
    explicit ArchConfig (used by examples that build custom configs).
    """

    def __init__(self, spec: TrainSpec, *, cfg=None, mesh=None):
        from repro.configs import get_config
        from repro.optim import make_optimizer
        from repro.optim.schedules import constant

        self.spec = spec.validate()
        if cfg is None:
            cfg = get_config(spec.arch)
            if spec.reduced:
                cfg = cfg.reduced()
        self.cfg = cfg
        self.opt = make_optimizer(spec.optimizer, constant(spec.lr))
        self.mesh = mesh if mesh is not None else self._auto_mesh(self.spec)
        self._live_spec: Optional[TrainSpec] = None
        self._switch_to(self.spec)

    @classmethod
    def from_spec(cls, spec: TrainSpec, *, cfg=None, mesh=None) -> "Trainer":
        return cls(spec, cfg=cfg, mesh=mesh)

    # -------------------------------------------------------------- sharding
    @staticmethod
    def _auto_mesh(spec: TrainSpec):
        """(data, model) mesh over the visible devices; ``None`` (unsharded,
        the historical single-device behaviour) with one device and
        ``model_parallel == 1``."""
        n = len(jax.devices())
        if n == 1 and spec.model_parallel == 1:
            return None
        from repro.runtime.elastic import make_mesh_from_devices
        return make_mesh_from_devices(jax.devices(), spec.model_parallel)

    def _with_mesh_act_spec(self, spec: TrainSpec) -> TrainSpec:
        """Fold the mesh's activation sharding into the spec (Megatron SP on
        the seq dim only when it divides). Under a Trainer-managed mesh
        act_spec is *derived* state, recomputed on every switch — a
        degradation rung that halves the batch or truncates the seq must not
        carry the old mesh geometry. Engines with a custom regime
        (``backend is None``, e.g. the ZO family) keep act_spec unset."""
        if self.mesh is None:
            return spec
        if get_engine(spec.engine).backend is None:
            return spec
        from repro.launch import sharding as sh
        msize = self.mesh.shape.get("model", 1)
        act = sh.activation_spec(
            self.mesh, spec.batch,
            seq_on_model=(msize > 1 and spec.seq % msize == 0))
        return dataclasses.replace(spec, act_spec=act)

    def _state_struct(self, spec: TrainSpec):
        """(params, opt_state) ShapeDtypeStructs for ``spec`` — no arrays."""
        from repro.models import model as model_lib

        def init():
            params = model_lib.init_params(
                jax.random.PRNGKey(self.spec.seed), self.cfg,
                quantize=spec.quantize)
            return params, self.opt.init(params)

        return jax.eval_shape(init)

    def shard_state(self, params, opt_state=None, *, mesh=None):
        """``device_put`` state onto the mesh's logical PartitionSpecs
        (placement-only — values are untouched, tested bit-exact). Returns
        ``params`` or ``(params, opt_state)`` mirroring the arguments."""
        from repro.launch import sharding as sh
        from repro.runtime.elastic import reshard_tree

        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            return params if opt_state is None else (params, opt_state)
        params = reshard_tree(params, mesh,
                              sh.param_specs(self.cfg, params, mesh))
        if opt_state is None:
            return params
        opt_state = reshard_tree(opt_state, mesh,
                                 sh.opt_specs(self.cfg, opt_state, mesh))
        return params, opt_state

    def resize(self, devices=None, *, model_parallel=None, params=None,
               opt_state=None):
        """Elastic resize: rebuild the mesh from the surviving ``devices``
        (default: all visible), re-jit the live spec's step for it, and —
        when ``params``/``opt_state`` are passed — reshard them onto the new
        topology (``runtime.elastic.reshard_tree``; placement-only).

        Returns ``None``, ``params`` or ``(params, opt_state)`` mirroring
        the state arguments. The optimizer trajectory across a resize is
        covered by the emulated-fleet suite (tests/multihost/)."""
        from repro.runtime.elastic import make_mesh_from_devices

        devices = list(devices) if devices is not None else jax.devices()
        if model_parallel is None:
            model_parallel = (self.mesh.shape.get("model", 1)
                              if self.mesh is not None
                              else self.live_spec.model_parallel)
        self.mesh = make_mesh_from_devices(devices, model_parallel)
        live = dataclasses.replace(self.live_spec, act_spec=None)
        self._live_spec = None    # force a re-jit onto the new mesh
        self._switch_to(live)
        if params is None:
            return None
        return self.shard_state(params, opt_state)

    # ------------------------------------------------------------ live spec
    def _switch_to(self, spec: TrainSpec) -> None:
        """(Re)build engine + jitted step for ``spec``; no-op if unchanged.
        Raises (without changing live state) when the engine refuses the
        spec — the degradation path uses that to skip unbuildable rungs.

        With a mesh, the step is jitted with explicit in/out shardings
        (params/opt state on ``launch/sharding.py``'s logical specs, batch
        on the DP axes, loss replicated) and wrapped to run inside the mesh
        context so ``with_sharding_constraint``/``mesh_axis_size`` see it."""
        spec = self._with_mesh_act_spec(spec)
        if spec == self._live_spec:
            return
        spec = spec.validate()
        engine: Engine = get_engine(spec.engine)
        policy = spec.policy()
        build = engine.build_step(spec, self.cfg, self.opt, policy)
        if self.mesh is None:
            step_fn = jitted = jax.jit(build)
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.launch import sharding as sh

            mesh = self.mesh
            pstruct, ostruct = self._state_struct(spec)
            pshard = sh.named(mesh, sh.param_specs(self.cfg, pstruct, mesh))
            oshard = sh.named(mesh, sh.opt_specs(self.cfg, ostruct, mesh))
            bspec = sh.batch_spec(mesh, spec.batch)
            bdim = bspec[0] if len(bspec) else None
            # pytree prefix: every batch leaf shards its leading (batch) dim
            bshard = NamedSharding(mesh, P(bdim))
            jitted = jax.jit(
                build, in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, NamedSharding(mesh, P())))

            def step_fn(params, opt_state, batch, _j=jitted, _m=mesh):
                with _m:
                    return _j(params, opt_state, batch)

        self.engine, self.policy, self.step_fn = engine, policy, step_fn
        #: the raw jitted step (no mesh-context wrapper) — ``.lower()`` this
        #: for compiled-HLO inspection (fleet collective-bytes checks)
        self._jit_step = jitted
        self._live_spec = spec

    @property
    def live_spec(self) -> TrainSpec:
        """The spec currently compiled (post-degradation, if any)."""
        return self._live_spec or self.spec

    # ---------------------------------------------------------------- state
    def init_state(self):
        from repro.models import model as model_lib

        live = self.live_spec
        params = model_lib.init_params(
            jax.random.PRNGKey(self.spec.seed), self.cfg,
            quantize=live.quantize)
        return params, self.opt.init(params)

    def make_data(self, state=None):
        from repro.data import make_batch_iterator

        live = self.live_spec
        return make_batch_iterator(
            self.cfg.vocab, live.seq, live.batch,
            host_index=jax.process_index(), host_count=jax.process_count(),
            seed=self.spec.seed, state=state)

    # ------------------------------------------------------------------ fit
    def fit(self, steps: Optional[int] = None, *,
            data=None, on_step: Optional[Callable] = None,
            straggler=None, telemetry=None) -> TrainResult:
        """Run ``steps`` (default: spec.steps) supervised resilient training
        steps, resuming from the latest checkpoint in ``spec.ckpt_dir`` if
        any. Fault injection, the degradation ladder and the step guard are
        all driven by the spec's resilience fields; observability by the
        spec's telemetry fields (or an explicitly passed ``telemetry``)."""
        from repro import telemetry as tele
        from repro.checkpoint import Checkpointer
        from repro.data.pipeline import DataState, TokenStream
        from repro.runtime import degrade as degrade_mod
        from repro.runtime import faults as faults_mod
        from repro.runtime.fault_tolerance import ResilientLoop, \
            StragglerPolicy
        from repro.runtime.guard import StepGuard

        spec0 = self.spec
        total = steps if steps is not None else spec0.steps
        self._switch_to(spec0)
        ckpt = Checkpointer(spec0.ckpt_dir, interval=spec0.ckpt_interval)

        tel = telemetry if telemetry is not None \
            else tele.Telemetry.from_spec(spec0)
        injector = None
        if spec0.inject_faults:
            plan = faults_mod.FaultPlan.from_string(
                spec0.inject_faults, total_steps=total, seed=spec0.seed)
            injector = faults_mod.FaultInjector(plan,
                                               ckpt_dir=spec0.ckpt_dir)
            log.warning("chaos run: injecting faults [%s]", plan.to_string())
            if tel.enabled:
                injector.on_fire = lambda step, kind: tel.emit(
                    tele.FaultEvent(step=step, fault=kind, injected=True,
                                    source="injector"))
        guard = (StepGuard(budget=spec0.guard_budget,
                           telemetry=tel if tel.enabled else None)
                 if spec0.guard == "on" else None)
        ladder = (degrade_mod.DegradationLadder()
                  if spec0.degrade == "on" else None)
        straggler = straggler or StragglerPolicy(
            factor=spec0.straggler_factor,
            consecutive_limit=spec0.straggler_limit)
        # watermark monitor: on for telemetry runs, and whenever a memory
        # budget asks for proactive (pre-OOM) pressure handling
        memwatch = (tele.MemoryWatermark()
                    if tel.enabled or spec0.mem_budget_mb > 0 else None)
        if memwatch is not None:
            memwatch.predicted_mb = degrade_mod.predicted_peak_mb(
                self.live_spec) or 0.0
        pressure = (degrade_mod.WatermarkTrigger(spec0.mem_budget_mb)
                    if spec0.mem_budget_mb > 0 and ladder is not None
                    else None)

        def _log_step(res):
            tele.log_step(res, spec0.log_interval, quiet=spec0.quiet)
            if on_step:
                on_step(res)

        def extra_fn():
            return {"spec": _spec_manifest(self.live_spec)}

        def _sync_iter(loop, state):
            """Point the loop at an iterator matching the live spec's
            (seq, batch) positioned at ``state``."""
            live = self.live_spec
            if data is None:
                loop.batch_iter = self.make_data(state=state)
                return
            it = loop.batch_iter
            if state is not None:
                it.state = state
            elif loop._initial_data_state is not None:
                it.state = dataclasses.replace(loop._initial_data_state)
            if isinstance(it, TokenStream) and (it.seq_len != live.seq
                                                or it.batch != live.batch):
                loop.batch_iter = TokenStream(it.tokens, live.seq,
                                              live.batch, state=it.state)

        def restore_fn(loop):
            def template_fn(extra):
                saved = (extra or {}).get("spec")
                target = (dataclasses.replace(spec0, **saved) if saved
                          else spec0)
                self._switch_to(target)
                return self.init_state()

            try:
                restored = ckpt.restore_latest(template_fn=template_fn)
            except IOError as e:
                # every checkpoint corrupt: restart from step 0 rather
                # than lose the job (counters record the quarantines)
                log.error("all checkpoints unrestorable (%s); "
                          "restarting from scratch", e)
                restored = None
            if restored is None:
                self._switch_to(spec0)
                params, opt_state = self.init_state()
                _sync_iter(loop, None)
                loop.step_fn = self.step_fn
                return 0, params, opt_state
            log.info("resuming from step %d (engine=%s batch=%d seq=%d "
                     "quantize=%s)", restored["step"], self.live_spec.engine,
                     self.live_spec.batch, self.live_spec.seq,
                     self.live_spec.quantize)
            state = (DataState.from_dict(restored["data_state"])
                     if restored["data_state"] else None)
            _sync_iter(loop, state)
            loop.step_fn = self.step_fn
            return restored["step"], restored["params"], restored["opt_state"]

        def on_oom(loop):
            if ladder is None:
                return None
            live = self.live_spec
            try:
                cands = list(ladder.candidates(live))
            except degrade_mod.LadderExhausted as e:
                log.error("OOM with no rung left: %s", e)
                return None
            for cand, rung in cands:
                new_it = loop.batch_iter
                if cand.batch != live.batch or cand.seq != live.seq:
                    if not isinstance(new_it, TokenStream):
                        continue    # can't re-window an opaque iterator
                    new_it = TokenStream(new_it.tokens, cand.seq, cand.batch,
                                         state=new_it.state)
                try:
                    self._switch_to(cand)
                except Exception as e:
                    log.debug("rung %s unbuildable: %s", rung, e)
                    continue
                params, opt_state = loop.params, loop.opt_state
                if cand.quantize != live.quantize:
                    from repro.core.quant import quantize_params
                    new_params = quantize_params(params, cand.quantize)
                    opt_state = degrade_mod.carry_opt_state(
                        opt_state, params, new_params)
                    params = new_params
                loop.batch_iter = new_it
                loop.step_fn = self.step_fn
                ladder.record(rung)
                pred = degrade_mod.predicted_peak_mb(cand)
                if memwatch is not None:
                    memwatch.predicted_mb = pred or 0.0
                if tel.enabled:
                    tel.emit(tele.DegradeEvent(
                        step=loop.step, rung=rung,
                        trigger=loop.degrade_trigger, engine=cand.engine,
                        quantize=cand.quantize, batch=cand.batch,
                        seq_len=cand.seq, predicted_peak_mb=pred or 0.0))
                    tel.registry.counter("degrade.rungs").inc()
                log.warning(
                    "memory pressure: degraded via %r -> engine=%s batch=%d "
                    "seq=%d quantize=%s (predicted peak %.0f MB)",
                    rung, cand.engine, cand.batch, cand.seq, cand.quantize,
                    pred or float("nan"))
                return params, opt_state
            return None

        it = data if data is not None else self.make_data()
        loop = ResilientLoop(
            self.step_fn, self.init_state, it, ckpt, total,
            max_retries=spec0.max_retries,
            restart_budget=8,    # supervised straggler restarts per run
            straggler=straggler, guard=guard, injector=injector,
            on_step=_log_step, on_oom=on_oom, restore_fn=restore_fn,
            extra_fn=extra_fn, telemetry=tel, memwatch=memwatch,
            pressure=pressure)
        if tel.enabled:
            tel.emit(tele.RunEvent(
                phase="start", engine=spec0.engine, quantize=spec0.quantize,
                arch=spec0.arch, spec=_spec_manifest(spec0)))
        try:
            params, opt_state, history, counters = loop.run()
            if tel.enabled:
                tel.emit(tele.RunEvent(
                    phase="end", engine=self.live_spec.engine,
                    quantize=self.live_spec.quantize, arch=spec0.arch,
                    steps=len(history),
                    final_loss=float(history[-1].loss) if history else None))
        finally:
            if telemetry is None:   # fit owns the lifecycle it created
                tel.close()
        metrics: dict = {}
        if guard is not None:
            metrics["guard"] = guard.state()
        if memwatch is not None:
            metrics["watermark"] = memwatch.compare()
        if tel.enabled:
            metrics["registry"] = tel.registry.snapshot()
            metrics["events_by_kind"] = tel.counts_by_kind()
            metrics["spans"] = tel.tracer.totals()
            metrics["telemetry_dir"] = tel.out_dir
        return TrainResult(
            params=params, opt_state=opt_state, history=history,
            counters=counters, final_spec=self.live_spec,
            degradations=list(ladder.applied) if ladder else [],
            metrics=metrics)
