"""ExecutionPolicy: the single object that selects *how* the model executes.

Before this existed, the backward regime (``mode="structured"|"pallas"|...``),
the activation sharding spec and the quantize method were threaded as loose
kwargs through ``core/mesp.py`` → ``models/model.py`` → ``models/layers.py``
→ ``kernels/ops.py`` (14 call sites).  ExecutionPolicy replaces all of them:
every layer of the model stack takes one ``policy`` argument and reads the
fields it cares about.

The object is a *static* (hashable, frozen) configuration — it is closed
over by jitted step functions, never traced.  Fields:

* ``backend``       — backward regime for trainable-path ops:
    - ``structured`` — the paper's hand-derived custom_vjp rules (MeSP),
    - ``pallas``     — the same rules fused into Pallas TPU kernels,
    - ``plain``      — framework autodiff (MeBP baseline),
    - ``store_h``    — MeSP with ``h = x@A`` stored (paper Table 5 ablation).
* ``quantize``      — frozen-W0 format the params were initialised with
  (a ``core.quant.METHODS`` entry: ``none`` | ``int8`` | packed ``int4`` |
  ``nf4``); carried so engines/launchers can validate support.
* ``act_spec``      — block-boundary activation sharding constraint
  (a ``PartitionSpec``), or None.
* ``flash_min_seq`` — sequence length at/above which the structured backend
  uses the chunked flash path instead of the dense sdpa.
* ``flash_chunk``   — q/k chunk size for that flash path.
* ``remat``         — per-block rematerialization (``jax.checkpoint`` around
  the scan body, the paper's §4.3 store-block-inputs-only schedule).
* ``interpret``     — force the Pallas interpreter on/off (None = auto:
  interpret off-TPU).
* ``fuse_rope``     — pallas backend only: rotate q/k inside the flash
  kernels (cos/sin tables streamed per tile, rotated q/k never
  materialized in HBM) instead of the separate jnp RoPE pass. Gradients
  are identical ≤1e-5; architectures without RoPE (rwkv6, griffin,
  whisper) are unaffected.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

#: valid ``backend`` values accepted throughout the model stack
BACKENDS = ("structured", "pallas", "plain", "store_h")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    backend: str = "structured"
    quantize: str = "none"
    act_spec: Any = None
    flash_min_seq: int = 1024
    flash_chunk: int = 1024
    remat: bool = True
    interpret: Optional[bool] = None
    fuse_rope: bool = False

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")

    @classmethod
    def from_mode(cls, mode: Optional[str] = None, act_spec=None,
                  **kw) -> "ExecutionPolicy":
        """Adapter for the legacy ``mode=`` string API (``core/mesp.py``
        still accepts it for back-compat and folds it into a policy here)."""
        return cls(backend=mode or "structured", act_spec=act_spec, **kw)

    def with_(self, **kw) -> "ExecutionPolicy":
        return dataclasses.replace(self, **kw)


#: shared default instances (module-level so identity-based jit caching of
#: closures over them is maximally effective)
STRUCTURED = ExecutionPolicy()
PALLAS = ExecutionPolicy(backend="pallas")
PLAIN = ExecutionPolicy(backend="plain")
STORE_H = ExecutionPolicy(backend="store_h")
