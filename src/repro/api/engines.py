"""Built-in engine registrations.

Importing this module (done lazily by the registry) registers the paper's
engines: ``mesp`` (§4, production scan form), ``mesp_seq`` (§4.3 sequential
loop with immediate optimizer updates), ``mesp_pallas`` (§4 fused into
Pallas TPU kernels), ``mebp`` (§3.3 autodiff baseline), ``store_h``
(Table 5 ablation), and — via ``repro.zo.engines`` — the zeroth-order
family: ``mezo`` (§3.2 baseline) plus the structured-sampler variants
``mezo_sparse`` / ``mezo_lowrank`` / ``mezo_block`` / ``mezo_avg4``.
"""
from __future__ import annotations

from repro.api.registry import register_engine


def _grad_builder(spec, cfg, opt, policy):
    """Shared step-builder for engines that are `mesp.value_and_grad` under
    a specific ExecutionPolicy backend."""
    from repro.core import mesp

    def step(params, opt_state, batch):
        loss, grads = mesp.value_and_grad(params, cfg, batch, policy=policy)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def _grad_vag(params, cfg, batch, *, policy, key=None):
    from repro.core import mesp
    return mesp.value_and_grad(params, cfg, batch, policy=policy)


register_engine(
    "mesp", backend="structured", memsim="mesp", paper="§4",
    value_and_grad=_grad_vag,
    description="MeSP: hand-derived structured backward (h recomputed), "
                "scan-over-blocks form")(_grad_builder)

register_engine(
    "mesp_pallas", backend="pallas", memsim="mesp", paper="§4 + kernels",
    value_and_grad=_grad_vag,
    # AOT-lowering interpret-mode Pallas kernels for the 0.5B–3B paper
    # models is not meaningful off-TPU; benchmarks/kernels.py covers this
    # engine's perf trajectory instead.
    benchmark=False,
    description="MeSP with the structured rules fused into Pallas TPU "
                "kernels: sparse-grid flash attention (causal/window tiles "
                "skipped at trace time), optional in-kernel RoPE "
                "(--fuse-rope); interpret mode off-TPU")(_grad_builder)

register_engine(
    "mebp", backend="plain", memsim="mebp", paper="§3.3",
    value_and_grad=_grad_vag,
    description="MeBP baseline: per-block checkpointing + framework "
                "autodiff")(_grad_builder)

register_engine(
    "store_h", backend="store_h", memsim="store_h", paper="Table 5",
    value_and_grad=_grad_vag,
    description="MeSP ablation: h = x@A stored instead of recomputed")(
    _grad_builder)


@register_engine(
    "mesp_seq", backend="structured", memsim="mesp", paper="§4.3",
    value_and_grad=_grad_vag,
    description="MeSP, paper §4.3 verbatim: reverse Python loop over "
                "blocks, SGD applied immediately per block (dense family)")
def _mesp_seq_builder(spec, cfg, opt, policy):
    from repro.core import mesp

    if cfg.family != "dense" or cfg.window_pattern:
        raise ValueError(
            "engine mesp_seq (paper §4.3) supports dense, non-patterned "
            f"architectures only — got family={cfg.family!r}")
    if spec.optimizer != "sgd":
        raise ValueError(
            "engine mesp_seq applies immediate per-block SGD (paper §4.3); "
            f"--optimizer {spec.optimizer!r} is not representable")
    lr = spec.lr

    def step(params, opt_state, batch):
        params, loss = mesp.sequential_train_step(params, cfg, batch, lr,
                                                  policy=policy)
        return params, {**opt_state, "step": opt_state["step"] + 1}, loss

    return step


# Zeroth-order engines (mezo + the structured variants) are registered by
# the pluggable ZO subsystem — one engine per sampler × query combination.
from repro.zo import engines as _zo_engines  # noqa: E402,F401  (self-registers)
