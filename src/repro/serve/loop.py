"""Continuous-batching multi-tenant decode loop.

Batch layout: ``slots`` decode rows, grouped into tiles of ``tile``
consecutive rows. Each tile is bound to at most one resident adapter slot;
the int32 ``[n_tiles]`` routing vector (``tile_gid``) is a *runtime* input
to the jitted decode step, so admission / recycling / adapter re-binding
never recompile — the grouped LoRA kernel gathers each tile's (A, B) pair
into VMEM by scalar-prefetched index (``kernels/lora_grouped.py``), and the
per-slot KV cache (``model.init_cache(per_slot=True)``) holds every row at
its own position.

Scheduling is step-granular continuous batching: at each step the admission
pass (FIFO with skip-ahead) places queued requests into compatible tiles,
then one ``decode_step`` advances every active row — prompt rows consume
their next prompt token (prefill-as-decode), generation rows feed back the
previously sampled token. Finished rows recycle immediately: pages return
to the :class:`~repro.serve.paged.PagedKVAllocator`, the adapter pin drops,
and an emptied tile unbinds so its adapter becomes evictable.

Admission gates, in order:
1. a compatible tile (same adapter with a free row, or a fully-idle tile);
2. KV pages for ``len(prompt) + max_new`` tokens (reserved up front — an
   admitted request can never die of allocator exhaustion mid-decode);
3. optional analytic memory headroom: ``mem_budget_mb`` against
   ``benchmarks/memsim.serve_residency`` (weights + resident adapters +
   live KV pages + decode working set).

Determinism: every row's math is independent of its neighbours (per-row
attention mask/positions, per-row adapter gather, greedy argmax), and a
row's cache lines are zeroed at assignment — so a request's token stream
depends only on its own prompt and adapter, not on arrival interleaving or
slot placement (asserted in tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policy import STRUCTURED, ExecutionPolicy
from repro.models import model as model_lib
from repro.serve.paged import PagedKVAllocator
from repro.serve.store import AdapterStore, StoreFull
from repro.telemetry import DISABLED as _NO_TELEMETRY
from repro.telemetry import AdmissionEvent
from repro.telemetry.metrics import CounterGroup, MetricRegistry

log = logging.getLogger("repro.serve")


@dataclasses.dataclass(frozen=True)
class Request:
    rid: str                  #: unique request id
    adapter: str              #: tenant/adapter uid (AdapterStore key)
    prompt: Tuple[int, ...]   #: prompt token ids (fed prefill-as-decode)
    max_new: int              #: tokens to generate after the prompt


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pending: List[int] = dataclasses.field(default_factory=list)
    last: int = 0
    out: List[int] = dataclasses.field(default_factory=list)


def _reset_slot(cache, b: int):
    """Zero slot ``b``'s rows across every cache leaf (fresh assignment —
    no state leaks from the row's previous occupant). Stacked leaves are
    ``[L, B, ...]`` (slot axis 1); the unstacked ``block0``/``enc_out``
    entries carry the slot axis at 0."""
    out = {}
    for key, sub in cache.items():
        ax = 0 if key in ("block0", "enc_out") else 1
        idx = (slice(None),) * ax + (b,)
        out[key] = jax.tree_util.tree_map(
            lambda l: l.at[idx].set(jnp.zeros_like(l[idx])), sub)
    return out


class ContinuousBatcher:
    """Multi-tenant continuous-batching decoder over an AdapterStore.

    ``register_adapter`` publishes a tenant's (A, B) tree to the host-side
    registry (the offload tier); the store pulls it into HBM residency on
    first admission and LRU-evicts it when unpinned and cold.
    """

    def __init__(self, cfg, store: AdapterStore, *, slots: int = 8,
                 tile: int = 2, max_len: int = 128, page_size: int = 16,
                 policy: ExecutionPolicy = STRUCTURED,
                 mem_budget_mb: Optional[float] = None,
                 weights_fmt: str = "bf16", rank: Optional[int] = None,
                 telemetry=None):
        if slots % tile:
            raise ValueError(f"slots ({slots}) must be a multiple of the "
                             f"tile size ({tile})")
        self.cfg = cfg
        self.store = store
        self.slots = slots
        self.tile = tile
        self.n_tiles = slots // tile
        self.max_len = max_len
        self.policy = policy
        self.mem_budget_mb = mem_budget_mb
        self.weights_fmt = weights_fmt
        self.rank = rank if rank is not None else cfg.lora.rank
        self.cache = model_lib.init_cache(cfg, slots, max_len, per_slot=True)
        self.alloc = PagedKVAllocator(slots * max_len // page_size, page_size)
        self.tile_adapter: List[Optional[str]] = [None] * self.n_tiles
        self.tile_gid = np.zeros(self.n_tiles, np.int32)
        self._rows = [_Slot() for _ in range(slots)]
        self._registry: Dict[str, object] = {}
        self.queue: List[Request] = []
        self.results: Dict[str, List[int]] = {}
        self.counters = CounterGroup(
            "serve", ("admitted", "completed", "steps", "prefill_tokens",
                      "decoded_tokens", "rejected_pages",
                      "rejected_headroom", "rejected_tiles",
                      "rejected_store"))
        # one namespaced registry over the three formerly-private counter
        # dicts (serve.* / store.* / pages.*); a telemetry object shares its
        # registry (and gains spans + admission events), otherwise the
        # batcher owns a local one — snapshot via .metrics()
        self._tel = telemetry if telemetry is not None else _NO_TELEMETRY
        self.registry = (telemetry.registry if telemetry is not None
                         else MetricRegistry())
        self.registry.register_group(self.counters)
        self.registry.register_group(self.store.counters)
        self.registry.register_group(self.alloc.counters)
        self._jstep = jax.jit(
            lambda p, c, t, g: model_lib.decode_step(
                p, cfg, c, t, policy=policy, adapter_tiles=g))

    # -- tenant registry ----------------------------------------------------

    def register_adapter(self, uid: str, adapters) -> None:
        self._registry[uid] = adapters

    # -- metrics ------------------------------------------------------------

    def metrics(self) -> Dict[str, object]:
        """Unified namespaced snapshot (serve.* / store.* / pages.*) —
        what ``benchmarks/serving.py`` reports."""
        return self.registry.snapshot()

    def _reject(self, req: Request, reason: str) -> bool:
        self.counters[f"rejected_{reason}"] += 1
        if self._tel.enabled:
            self._tel.emit(AdmissionEvent(
                action="reject", rid=req.rid, adapter=req.adapter,
                reason=reason, step=self.counters["steps"]))
        return False

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(f"request {req.rid!r} needs "
                             f"{len(req.prompt) + req.max_new} tokens but "
                             f"max_len is {self.max_len}")
        if req.adapter not in self._registry:
            raise KeyError(f"adapter {req.adapter!r} not registered")
        self.queue.append(req)

    def _tile_rows(self, t: int) -> range:
        return range(t * self.tile, (t + 1) * self.tile)

    def _find_tile(self, uid: str) -> Optional[int]:
        for t, bound in enumerate(self.tile_adapter):
            if bound == uid and any(self._rows[b].req is None
                                    for b in self._tile_rows(t)):
                return t
        for t, bound in enumerate(self.tile_adapter):
            if bound is None:
                return t
        return None

    def _headroom_ok(self, extra_adapter: bool, extra_tokens: int) -> bool:
        if self.mem_budget_mb is None:
            return True
        from repro.runtime.degrade import _import_memsim
        try:
            memsim = _import_memsim()
        except ImportError:
            return True          # stripped deployment: cannot validate
        resident = min(self.store.resident + (1 if extra_adapter else 0),
                       self.store.capacity)
        pages = self.alloc.used_pages + self.alloc.pages_for(extra_tokens)
        r = memsim.serve_residency(
            self.cfg, rank=self.rank, resident_adapters=resident,
            kv_pages=pages, page_size=self.alloc.page_size,
            batch=self.slots, weights_fmt=self.weights_fmt)
        return r["total_mb"] <= self.mem_budget_mb

    def _try_place(self, req: Request) -> bool:
        t = self._find_tile(req.adapter)
        if t is None:
            return self._reject(req, "tiles")
        if not self.store.can_admit(req.adapter):
            return self._reject(req, "store")
        total = len(req.prompt) + req.max_new
        if not self._headroom_ok(
                self.store.lookup(req.adapter) is None, total):
            return self._reject(req, "headroom")
        if not self.alloc.reserve(req.rid, total):
            return self._reject(req, "pages")
        try:
            slot = self.store.acquire(req.adapter,
                                      self._registry[req.adapter])
        except StoreFull:
            self.alloc.free(req.rid)
            return self._reject(req, "store")
        if self.tile_adapter[t] is None:
            self.tile_adapter[t] = req.adapter
        self.tile_gid[t] = slot
        b = next(i for i in self._tile_rows(t) if self._rows[i].req is None)
        self.cache = _reset_slot(self.cache, b)
        self._rows[b] = _Slot(req=req, pending=list(req.prompt))
        self.counters["admitted"] += 1
        if self._tel.enabled:
            self._tel.emit(AdmissionEvent(
                action="admit", rid=req.rid, adapter=req.adapter,
                step=self.counters["steps"]))
        return True

    def _admit(self) -> None:
        still = []
        for req in self.queue:          # FIFO with skip-ahead
            if not self._try_place(req):
                still.append(req)
        self.queue = still

    def _recycle(self, b: int) -> None:
        row = self._rows[b]
        self.alloc.free(row.req.rid)
        self.store.release(row.req.adapter)
        self.results[row.req.rid] = row.out
        self._rows[b] = _Slot()
        t = b // self.tile
        if all(self._rows[i].req is None for i in self._tile_rows(t)):
            self.tile_adapter[t] = None   # adapter now evictable
        self.counters["completed"] += 1
        if self._tel.enabled:
            self._tel.emit(AdmissionEvent(
                action="complete", rid=row.req.rid, adapter=row.req.adapter,
                step=self.counters["steps"]))

    # -- decode -------------------------------------------------------------

    @property
    def active(self) -> int:
        return sum(r.req is not None for r in self._rows)

    def step(self) -> bool:
        """Admit, then advance every active row by one token. Returns False
        when there is nothing to do (no active rows, empty queue)."""
        tel = self._tel
        if tel.enabled:
            with tel.span("admission"):
                self._admit()
        else:
            self._admit()
        if self.active == 0:
            return False
        toks = np.zeros((self.slots, 1), np.int32)
        prefilling = any(r.req is not None and r.pending for r in self._rows)
        for b, row in enumerate(self._rows):
            if row.req is not None:
                toks[b, 0] = row.pending[0] if row.pending else row.last
        if tel.enabled:
            # prefill runs through the same step (prefill-as-decode); the
            # span name records which phase this step predominantly served
            with tel.span("prefill" if prefilling else "decode"):
                logits, self.cache = self._jstep(
                    self.store.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(self.tile_gid))
        else:
            logits, self.cache = self._jstep(
                self.store.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.tile_gid))
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        self.counters["steps"] += 1
        done = []
        for b, row in enumerate(self._rows):
            if row.req is None:
                continue
            if row.pending:
                row.pending.pop(0)
                self.counters["prefill_tokens"] += 1
                if row.pending:
                    continue          # still prefilling; logits unused
            row.last = int(nxt[b])
            row.out.append(row.last)
            self.counters["decoded_tokens"] += 1
            if len(row.out) >= row.req.max_new:
                done.append(b)
        for b in done:
            self._recycle(b)
        return True

    def run(self, requests: Sequence[Request] = (),
            max_steps: int = 100_000) -> Dict[str, List[int]]:
        """Drain ``requests`` (plus anything already queued/active) to
        completion; returns {rid: generated tokens} for all completions
        (``self.results`` accumulates across calls)."""
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if not self.step():
                break
        if self.queue or self.active:
            raise RuntimeError(
                f"serve loop stalled: {len(self.queue)} queued, "
                f"{self.active} active after {self.counters['steps']} steps "
                f"(requests too large for the slot/page budget?)")
        return self.results
