"""Multi-tenant adapter serving: AdapterStore residency, paged KV
accounting, and the continuous-batching decode loop (see docs/serving.md).
"""
from repro.serve.loop import ContinuousBatcher, Request
from repro.serve.paged import PagedKVAllocator
from repro.serve.store import AdapterStore, StoreFull, synthetic_adapters

__all__ = ["AdapterStore", "StoreFull", "PagedKVAllocator",
           "ContinuousBatcher", "Request", "synthetic_adapters"]
