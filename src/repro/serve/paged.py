"""Paged KV-cache accounting for the continuous-batching serve loop.

The physical decode cache is slot-dense (``model.init_cache`` with
``per_slot=True``: one ``[B, Hkv, S, D]`` buffer per layer, per-slot length
vector). What *varies* at runtime is how much of that capacity is logically
live — and that is what admission control and the memory simulator need to
reason about. This allocator provides the vLLM-style page ledger over the
dense buffers: a fixed pool of fixed-size token pages, reserved per request
at admission (prompt + max_new, so a running request can never hit an
out-of-memory mid-decode) and returned at recycle.

A page map per owner is maintained (``pages_of``) — the indirection table a
gather-based paged-attention kernel would consume; the current dense
attention path only uses the ledger's counts, which is made explicit here
so the accounting (admission, ``benchmarks/memsim.serve_residency``) stays
honest about what is physical vs logical.
"""
from __future__ import annotations

from typing import Dict, List

from repro.telemetry.metrics import CounterGroup


class PagedKVAllocator:
    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._owned: Dict[str, List[int]] = {}
        # dict-compatible; namespaced "pages.*" when adopted by a batcher's
        # metric registry (repro.telemetry.metrics)
        self.counters = CounterGroup(
            "pages", ("reserved", "freed", "peak_pages", "rejected"))

    def pages_for(self, tokens: int) -> int:
        return -(-max(tokens, 0) // self.page_size)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def free_tokens(self) -> int:
        return len(self._free) * self.page_size

    def can_reserve(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= len(self._free)

    def reserve(self, owner: str, tokens: int) -> bool:
        """Reserve pages for ``tokens`` total KV entries; False (and a
        ``rejected`` count) when the pool can't cover them."""
        if owner in self._owned:
            raise KeyError(f"owner {owner!r} already holds pages")
        n = self.pages_for(tokens)
        if n > len(self._free):
            self.counters["rejected"] += 1
            return False
        self._owned[owner] = [self._free.pop() for _ in range(n)]
        self.counters["reserved"] += n
        self.counters["peak_pages"] = max(self.counters["peak_pages"],
                                          self.used_pages)
        return True

    def free(self, owner: str) -> int:
        """Return ``owner``'s pages to the pool (recycle); count freed."""
        pages = self._owned.pop(owner, [])
        self._free.extend(reversed(pages))
        self.counters["freed"] += len(pages)
        return len(pages)

    def pages_of(self, owner: str) -> List[int]:
        return list(self._owned.get(owner, ()))
