"""Bounded multi-tenant adapter residency: the HBM tier of LoRA serving.

An :class:`AdapterStore` owns the *stacked* serving parameter tree: every
LoRA pair of the base model is widened to ``a: [R, d_in, r]`` /
``b: [R, r, d_out]`` for ``R = capacity`` resident adapter slots, while the
frozen leaves (``w`` dense or int8 ``{"q","scale"}``, biases, norms,
embeddings) are shared across all tenants — one base model, ``R`` deltas.
The stacked tree is exactly what the grouped decode path consumes
(:func:`repro.models.layers.apply_linear` with ``adapter_tiles`` routing,
backed by ``kernels/lora_grouped.py``).

Residency is LRU with pinning: slots referenced by running requests are
pinned and never evicted; an insert into a full store evicts the
least-recently-used *unpinned* tenant or raises :class:`StoreFull`. Writes
are functional ``.at[slot].set`` updates keyed by parameter path, so a
quantized base (whose ``w`` leaves are ``{"q","scale"}`` dicts) and a plain
adapter tree compose without structure surgery — and because slot writes
only change leaf *values*, admission never retraces the jitted decode step.

Byte accounting (``slot_bytes`` / ``allocated_bytes``) feeds the serve-side
memory simulator (``benchmarks/memsim.serve_residency``) and the batcher's
admission headroom check.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.telemetry.metrics import CounterGroup


class StoreFull(RuntimeError):
    """Insert needed but every resident slot is pinned by a live request."""


def _adapter_leaves(tree) -> Dict[str, jax.Array]:
    """Path-keyed LoRA leaves (final key 'a' or 'b') of a param tree."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        keys = [getattr(k, "key", None) for k in path]
        if keys and keys[-1] in ("a", "b"):
            out[jax.tree_util.keystr(path)] = leaf
    return out


def synthetic_adapters(params, seed: int, scale: float = 0.05):
    """Deterministic per-tenant (A, B) tree for benchmarks/tests/demos:
    every LoRA leaf redrawn from a ``fold_in``-derived subkey (B nonzero, so
    tenants produce genuinely different deltas). Leaf order is path-sorted —
    stable across processes, unlike ``hash``-keyed schemes."""
    idx = {p: i for i, p in enumerate(sorted(_adapter_leaves(params)))}
    base = jax.random.PRNGKey(seed)

    def draw(path, leaf):
        i = idx.get(jax.tree_util.keystr(path))
        if i is None:
            return leaf
        k = jax.random.fold_in(base, i)
        return (scale * jax.random.normal(k, leaf.shape)).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(draw, params)


class AdapterStore:
    """LRU-bounded resident set of per-tenant LoRA (A, B) pairs.

    ``params``: the base model tree (``model.init_params``; its own a/b
    values are *not* served — slots start zeroed, i.e. identity deltas).
    ``capacity``: number of resident tenants R.
    """

    def __init__(self, params, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._paths = set(_adapter_leaves(params))
        if not self._paths:
            raise ValueError("base params carry no LoRA (a, b) leaves")
        if any("moe" in p for p in self._paths):
            raise ValueError(
                "multi-tenant AdapterStore does not support per-expert MoE "
                "adapters (expert stacks already consume the group axis); "
                "serve dense/vlm archs")
        # tenant axis goes just BEFORE the trailing (d_in, r)/(r, d_out)
        # matrix dims: any leading dims are layer/group stacking that the
        # decode scan slices away first, leaving [R, ., .] per layer —
        # the shape apply_linear's stacked-adapter branch routes on.
        mask = model_lib.trainable_mask(params)
        self.params = jax.tree_util.tree_map(
            lambda p, m: jnp.zeros(
                p.shape[:-2] + (capacity,) + p.shape[-2:], p.dtype)
            if m else p, params, mask)
        self._slot_of: "OrderedDict[str, int]" = OrderedDict()  # LRU order
        self._free = list(range(capacity - 1, -1, -1))          # pop() -> 0,1,..
        self._pins: Dict[str, int] = {}
        # dict-compatible; namespaced "store.*" when adopted by a batcher's
        # metric registry (repro.telemetry.metrics)
        self.counters = CounterGroup(
            "store", ("hits", "misses", "evictions", "inserts"))

    # -- byte accounting ----------------------------------------------------

    @property
    def slot_bytes(self) -> int:
        """Bytes one resident adapter occupies (its a/b leaves)."""
        flat = _adapter_leaves(self.params)
        return sum(l.size // self.capacity * l.dtype.itemsize
                   for l in flat.values())

    @property
    def allocated_bytes(self) -> int:
        """Bytes of the full stacked a/b allocation (capacity slots,
        preallocated — residency is which slots hold live tenants)."""
        return self.slot_bytes * self.capacity

    @property
    def resident(self) -> int:
        return len(self._slot_of)

    def pinned(self, uid: str) -> bool:
        return self._pins.get(uid, 0) > 0

    # -- residency ----------------------------------------------------------

    def lookup(self, uid: str) -> Optional[int]:
        return self._slot_of.get(uid)

    def can_admit(self, uid: str) -> bool:
        """Would :meth:`acquire` succeed without raising StoreFull? (Cheap
        pre-check so a batcher can reject before touching LRU counters.)"""
        return (uid in self._slot_of or bool(self._free)
                or any(not self.pinned(u) for u in self._slot_of))

    def acquire(self, uid: str, adapters=None, *, pin: bool = True) -> int:
        """Slot of ``uid``, inserting (and LRU-evicting) on miss.

        ``adapters``: tree holding the tenant's a/b leaves at the base
        model's paths (a full ``init_params`` tree works) — required on a
        miss. ``pin`` guards the slot against eviction until the matching
        :meth:`release`.
        """
        slot = self._slot_of.get(uid)
        if slot is not None:
            self.counters["hits"] += 1
            self._slot_of.move_to_end(uid)
        else:
            self.counters["misses"] += 1
            if adapters is None:
                raise KeyError(f"adapter {uid!r} not resident and no "
                               "adapter tree supplied")
            slot = self._insert(uid, adapters)
        if pin:
            self._pins[uid] = self._pins.get(uid, 0) + 1
        return slot

    def release(self, uid: str) -> None:
        n = self._pins.get(uid, 0)
        if n <= 1:
            self._pins.pop(uid, None)
        else:
            self._pins[uid] = n - 1

    def _insert(self, uid: str, adapters) -> int:
        if self._free:
            slot = self._free.pop()
        else:
            victim = next((u for u in self._slot_of if not self.pinned(u)),
                          None)
            if victim is None:
                raise StoreFull(
                    f"all {self.capacity} resident adapters are pinned")
            slot = self._slot_of.pop(victim)
            self.counters["evictions"] += 1
        leaves = _adapter_leaves(adapters)
        missing = self._paths - set(leaves)
        if missing:
            raise ValueError(f"adapter {uid!r} missing LoRA leaves: "
                             f"{sorted(missing)}")

        def write(path, stacked):
            leaf = leaves.get(jax.tree_util.keystr(path))
            if leaf is None:
                return stacked
            return stacked.at[..., slot, :, :].set(
                leaf.astype(stacked.dtype))

        self.params = jax.tree_util.tree_map_with_path(write, self.params)
        self._slot_of[uid] = slot
        self.counters["inserts"] += 1
        return slot
