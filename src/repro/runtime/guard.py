"""Anomaly-guarded steps: NaN/Inf-loss and update-norm-spike rejection.

On-device runs hit numerical blowups (a bad batch, a race with the
platform's power management downclocking mid-reduction) that a server fleet
would catch in aggregate dashboards. Here the defence is local: every step's
loss (and optionally the parameter-update norm, which for SGD is
``lr·‖grad‖``) is checked *before* the update is committed. An anomalous
step is rewound — the freshly computed params/opt-state are discarded, the
batch is skipped — and the run continues on the next batch.

The budget is bounded: more than ``budget`` rejected steps per run raises
:class:`GuardExhausted`, because a model that keeps producing NaNs is
diverged, not unlucky, and silently skipping forever would burn the
device's energy budget on garbage.

Observability: every rejection is categorized into one of :data:`REASONS`
and counted in ``by_reason``; :meth:`StepGuard.state` exposes the EWMAs and
counts (reported by ``benchmarks/resilience.py``), and with a telemetry
object attached each rejection emits a typed ``guard`` event and updates
``guard.*`` gauges on the metric registry.
"""
from __future__ import annotations

import logging
import math
from typing import Optional

import jax
import jax.numpy as jnp

log = logging.getLogger("repro.guard")

#: rejection categories, in check order
REASONS = ("nonfinite_loss", "nonfinite_norm", "loss_spike", "norm_spike")


class GuardExhausted(RuntimeError):
    """Raised when a run rejects more steps than its guard budget allows."""


def update_norm(old_params, new_params) -> float:
    """Global L2 norm of the parameter update over float leaves (LoRA
    factors; frozen int8 leaves are unchanged and skipped)."""
    total = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(old_params),
                    jax.tree_util.tree_leaves(new_params)):
        if not jnp.issubdtype(jnp.asarray(a).dtype, jnp.inexact):
            continue
        d = (jnp.asarray(b, jnp.float32) - jnp.asarray(a, jnp.float32))
        total += float(jnp.sum(d * d))
    return math.sqrt(total)


class StepGuard:
    """Accept/reject verdicts over a run's step stream.

    * non-finite loss → reject, always;
    * loss > ``spike_factor`` × EWMA(loss) after ``warmup`` accepted
      steps → reject;
    * update_norm > ``spike_factor`` × EWMA(norm) after ``warmup``
      accepted steps → reject (the grad-norm-spike guard; the loop passes
      the norm only when ``track_update_norm`` is set).

    Rejections consume a bounded ``budget``; exceeding it raises
    :class:`GuardExhausted`. EWMAs update on accepted steps only, so an
    anomaly never poisons its own baseline.
    """

    def __init__(self, budget: int = 8, spike_factor: float = 25.0,
                 alpha: float = 0.2, warmup: int = 8,
                 track_update_norm: bool = True, telemetry=None):
        self.budget = budget
        self.spike_factor = spike_factor
        self.alpha = alpha
        self.warmup = warmup
        self.track_update_norm = track_update_norm
        self.rejected = 0
        self.by_reason = {r: 0 for r in REASONS}
        self._accepted = 0
        self._loss_ewma: Optional[float] = None
        self._norm_ewma: Optional[float] = None
        self.telemetry = telemetry

    def state(self) -> dict:
        """EWMA state + per-reason counts (TrainResult.metrics["guard"],
        reported by benchmarks/resilience.py)."""
        return {"accepted": self._accepted, "rejected": self.rejected,
                "budget": self.budget,
                "loss_ewma": self._loss_ewma, "norm_ewma": self._norm_ewma,
                "by_reason": dict(self.by_reason)}

    def _reject(self, reason: str, detail: str, step: Optional[int]) -> str:
        self.rejected += 1
        self.by_reason[reason] += 1
        log.warning("step guard: rejecting step (%s), %d/%d budget used",
                    detail, self.rejected, self.budget)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            from repro.telemetry import GuardEvent
            tel.emit(GuardEvent(
                step=step if step is not None else -1, reason=reason,
                detail=detail, loss_ewma=self._loss_ewma,
                norm_ewma=self._norm_ewma, rejected=self.rejected,
                budget=self.budget))
            tel.registry.counter(f"guard.reject.{reason}").inc()
            tel.registry.gauge("guard.rejected").set(self.rejected)
        if self.rejected > self.budget:
            raise GuardExhausted(
                f"step guard budget exhausted: {self.rejected} anomalous "
                f"steps rejected (budget {self.budget}); last: {detail}")
        return "reject"

    def observe(self, loss: float, update_norm: Optional[float] = None,
                step: Optional[int] = None) -> str:
        """Returns ``"accept"`` or ``"reject"``; raises on exhausted budget."""
        if not math.isfinite(loss):
            return self._reject("nonfinite_loss",
                                f"non-finite loss {loss}", step)
        if update_norm is not None and not math.isfinite(update_norm):
            return self._reject("nonfinite_norm",
                                f"non-finite update norm {update_norm}", step)
        warmed = self._accepted >= self.warmup
        if (warmed and self._loss_ewma is not None
                and loss > self.spike_factor * self._loss_ewma):
            return self._reject(
                "loss_spike",
                f"loss spike {loss:.4g} > {self.spike_factor:g}x EWMA "
                f"{self._loss_ewma:.4g}", step)
        if (warmed and update_norm is not None
                and self._norm_ewma is not None and self._norm_ewma > 0
                and update_norm > self.spike_factor * self._norm_ewma):
            return self._reject(
                "norm_spike",
                f"update-norm spike {update_norm:.4g} > "
                f"{self.spike_factor:g}x EWMA {self._norm_ewma:.4g}", step)
        # accepted: fold into the baselines
        self._accepted += 1
        a = self.alpha
        self._loss_ewma = (loss if self._loss_ewma is None
                           else (1 - a) * self._loss_ewma + a * loss)
        if update_norm is not None:
            self._norm_ewma = (update_norm if self._norm_ewma is None
                               else (1 - a) * self._norm_ewma
                               + a * update_norm)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.registry.gauge("guard.loss_ewma").set(self._loss_ewma)
            if self._norm_ewma is not None:
                tel.registry.gauge("guard.norm_ewma").set(self._norm_ewma)
        return "accept"
