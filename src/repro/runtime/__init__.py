from repro.runtime import (degrade, elastic, fault_tolerance, faults,  # noqa: F401
                           guard)
