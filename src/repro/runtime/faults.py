"""Deterministic, seeded fault injection for chaos-hardening the trainer.

The paper's setting is a phone: 6-12 GB shared with every other workload,
so the dominant end of a fine-tuning run is not a clean completion but an
OOM kill, a preemption mid-step, or background throttling. This module makes
those failures *first-class, reproducible inputs* to a training run:

* :class:`FaultPlan` — a declarative list of ``(step, kind)`` events, built
  either from an explicit string (``"oom@4,corrupt@9,crash@9,nan@14,
  stall@18:1.5"``) or deterministically from a seed
  (:meth:`FaultPlan.seeded`). The same plan string always produces the same
  failures at the same steps — chaos runs are replayable.
* :class:`FaultInjector` — the runtime hook the
  :class:`~repro.runtime.fault_tolerance.ResilientLoop` calls at the step
  boundary. Each event fires exactly once (a restart that rewinds past a
  fired event does not re-fire it), so an injected fault models one real
  incident, not a permanently broken device.

Fault kinds and what they exercise:

=========  ==================================================================
``oom``    raises :class:`InjectedOOM` (message mimics the runtime's
           ``RESOURCE_EXHAUSTED``) → the memory-pressure degradation ladder
           (``runtime/degrade.py``), falling back to retry-from-checkpoint.
``crash``  raises :class:`InjectedCrash` → supervised restart: restore from
           the latest checkpoint, replay the exact token stream.
``nan``    replaces the step's loss with NaN → the step guard
           (``runtime/guard.py``) rejects the update (skip-and-rewind).
``corrupt`` flips bytes in the newest checkpoint's arrays on disk → the next
           restore fails checksum verification and ``Checkpointer`` must
           quarantine it and fall back to the next-older valid checkpoint.
``stall``  sleeps ``arg`` seconds (default 1.0) inside the timed step → the
           straggler watchdog flags the step, and past its consecutive
           limit the supervisor restarts from checkpoint.
=========  ==================================================================

The CLI exposes plans via ``--inject-faults`` (``launch/train.py``); tests
and ``benchmarks/resilience.py`` reuse the same objects verbatim.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Optional, Tuple

import numpy as np

log = logging.getLogger("repro.faults")

#: recognised fault kinds, in the order simultaneous events fire at one step
#: (corrupt before crash so a same-step "corrupt,crash" pair exercises the
#: checkpoint-fallback path; raising kinds last so non-raising ones run)
KINDS = ("corrupt", "stall", "nan", "oom", "crash")

#: substrings identifying a real allocator/runtime OOM in exception text
OOM_MARKERS = ("RESOURCE_EXHAUSTED", "Out of memory", "out of memory",
               "Allocation failure", "OOM")


class InjectedOOM(RuntimeError):
    """Simulated allocator exhaustion (message mimics RESOURCE_EXHAUSTED)."""


class InjectedCrash(RuntimeError):
    """Simulated process death: in-memory state is presumed lost."""


def is_oom_error(e: BaseException) -> bool:
    """True for injected OOMs, MemoryError, and runtime errors whose text
    matches the platform's resource-exhaustion messages."""
    if isinstance(e, (InjectedOOM, MemoryError)):
        return True
    msg = str(e)
    return any(m in msg for m in OOM_MARKERS)


def corrupt_latest_checkpoint(directory: str) -> Optional[int]:
    """Flip trailing bytes of one array file in the newest checkpoint so its
    content no longer matches the manifest checksum. Returns the corrupted
    step, or None if there is no checkpoint yet."""
    from repro.checkpoint.checkpointer import latest_step

    step = latest_step(directory)
    if step is None:
        return None
    d = os.path.join(directory, f"step_{step:08d}")
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    if not npys:
        return None
    path = os.path.join(d, npys[0])
    with open(path, "r+b") as f:
        f.seek(-8, os.SEEK_END)
        tail = f.read(8)
        f.seek(-8, os.SEEK_END)
        f.write(bytes(b ^ 0xFF for b in tail))
    return step


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str
    arg: float = 0.0      # stall: seconds to sleep

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")

    def to_string(self) -> str:
        base = f"{self.kind}@{self.step}"
        return f"{base}:{self.arg:g}" if self.arg else base


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, ordered set of fault events."""
    events: Tuple[FaultEvent, ...] = ()

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """``"oom@4,corrupt@9,crash@9,nan@14,stall@18:1.5"`` — a comma list
        of ``kind@step`` entries, with an optional ``:arg`` suffix."""
        events = []
        for part in filter(None, (p.strip() for p in text.split(","))):
            try:
                kind, rest = part.split("@", 1)
                step, _, arg = rest.partition(":")
                events.append(FaultEvent(int(step), kind.strip(),
                                         float(arg) if arg else 0.0))
            except ValueError as e:
                raise ValueError(
                    f"bad fault entry {part!r} (expected kind@step[:arg], "
                    f"kind in {KINDS}): {e}") from None
        return cls(tuple(sorted(events,
                                key=lambda ev: (ev.step,
                                                KINDS.index(ev.kind)))))

    @classmethod
    def seeded(cls, seed: int, total_steps: int, n_faults: int = 5,
               kinds: Tuple[str, ...] = KINDS) -> "FaultPlan":
        """Deterministic random plan: ``n_faults`` events at distinct steps
        in ``[1, total_steps-2]``, kinds drawn without immediate repeats.
        The same (seed, total_steps, n_faults) always yields the same plan."""
        rng = np.random.default_rng(seed)
        hi = max(2, total_steps - 1)
        n = min(n_faults, hi - 1)
        steps = sorted(rng.choice(np.arange(1, hi), size=n, replace=False))
        chosen = [kinds[i % len(kinds)] for i in rng.permutation(
            max(n, len(kinds)))[:n]]
        return cls(tuple(FaultEvent(int(s), k)
                         for s, k in zip(steps, chosen)))

    @classmethod
    def from_string(cls, text: str, *, total_steps: int = 100,
                    seed: int = 0) -> "FaultPlan":
        """CLI entry point: either an explicit ``kind@step`` list, or
        ``random`` / ``random:N`` for an N-event seeded plan over the run."""
        text = text.strip()
        if text.startswith("random"):
            _, _, n = text.partition(":")
            return cls.seeded(seed, total_steps,
                              n_faults=int(n) if n else 5)
        return cls.parse(text)

    def to_string(self) -> str:
        return ",".join(ev.to_string() for ev in self.events)

    def __bool__(self) -> bool:
        return bool(self.events)


class FaultInjector:
    """Fires a :class:`FaultPlan` into a running loop, once per event.

    The :class:`~repro.runtime.fault_tolerance.ResilientLoop` calls
    :meth:`before_step` inside its try block (raising kinds land in the
    loop's failure handler) and :meth:`after_step` on the produced loss.
    ``corrupt`` events that arrive before any checkpoint exists stay pending
    and fire at the first step boundary where one does.
    """

    def __init__(self, plan: FaultPlan, ckpt_dir: Optional[str] = None):
        self.plan = plan
        self.ckpt_dir = ckpt_dir
        self._fired: set = set()
        self.log: list = []          # (step_fired, kind) in firing order
        #: optional ``(step, kind) -> None`` hook fired on every injection
        #: (the trainer points this at telemetry so chaos timelines carry a
        #: typed event at the exact firing step, raising kinds included)
        self.on_fire = None

    def _fire(self, idx: int, step: int, ev: FaultEvent):
        self._fired.add(idx)
        self.log.append((step, ev.kind))
        log.warning("injecting fault %r (planned step %d) at step %d",
                    ev.kind, ev.step, step)
        if self.on_fire is not None:
            self.on_fire(step, ev.kind)

    def before_step(self, step: int) -> None:
        for idx, ev in enumerate(self.plan.events):
            if idx in self._fired or ev.kind in ("nan",):
                continue
            if ev.kind == "corrupt":
                # pending until a checkpoint exists to corrupt
                if ev.step <= step and self.ckpt_dir is not None:
                    if corrupt_latest_checkpoint(self.ckpt_dir) is not None:
                        self._fire(idx, step, ev)
                continue
            if ev.step != step:
                continue
            if ev.kind == "stall":
                self._fire(idx, step, ev)
                time.sleep(ev.arg or 1.0)
            elif ev.kind == "oom":
                self._fire(idx, step, ev)
                raise InjectedOOM(
                    f"RESOURCE_EXHAUSTED: injected OOM at step {step}")
            elif ev.kind == "crash":
                self._fire(idx, step, ev)
                raise InjectedCrash(f"injected process crash at step {step}")

    def after_step(self, step: int, loss):
        for idx, ev in enumerate(self.plan.events):
            if ev.kind == "nan" and ev.step == step and idx not in self._fired:
                self._fire(idx, step, ev)
                return float("nan")
        return loss

    def summary(self) -> dict:
        """``{kind: times_fired}`` — merged into the run's fault counters."""
        out: dict = {}
        for _, kind in self.log:
            out[kind] = out.get(kind, 0) + 1
        return out

    @property
    def exhausted(self) -> bool:
        return len(self._fired) == len(self.plan.events)
