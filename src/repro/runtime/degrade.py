"""Memory-pressure degradation ladder: keep training when the device shrinks.

On a phone the memory budget is not a constant — the OS reclaims pages as
other apps wake, and the correct response to ``RESOURCE_EXHAUSTED`` mid-run
is usually not "retry the identical program" (it will OOM again) but "retry
a cheaper program". This module walks the :class:`~repro.api.spec.TrainSpec`
space the engine registry already defines, rung by rung, most-reversible
first:

1. **halve the batch** (repeats until ``min_batch``) — linear activation
   savings, zero effect on the per-example gradient;
2. **engine step-down** — ``mesp_pallas → mesp → mesp_seq`` (the paper's
   §4.3 sequential loop: per-block immediate updates, the leanest retained
   set; requires the dense family + SGD, validated before the switch);
3. **quantize the frozen base to int8** — halves resident W0, LoRA factors
   and therefore gradients are untouched;
4. **re-quantize int8 → packed int4** — halves resident W0 again (two
   nibbles per byte, ``kernels/lora_pack4.py``); only offered once the int8
   rung is already in effect, so quantization error is added one notch at a
   time;
5. **halve the sequence length** (repeats until ``min_seq``) — last resort,
   it changes the token windows the run sees.

Every candidate rung is validated twice before it is offered: against the
registry (``TrainSpec.validate`` — the engine must support the resulting
quantize combo) and against ``benchmarks/memsim``'s analytical peak — a
rung that the memory model says does not reduce the predicted footprint is
skipped. The Trainer applies the first rung that also *builds* (e.g.
``mesp_seq`` refuses non-SGD optimizers at build time).

Optimizer state carries across compatible transitions:
batch/seq/engine rungs leave the param tree untouched, so the state carries
verbatim; the quantize rungs rewrite frozen ``w`` leaves into format dicts
(``{"q","scale"}`` int8, ``{"q4","scale"}`` packed int4), and
:func:`carry_opt_state` re-maps the state tree by parameter path so the
trained LoRA moments survive while frozen-slot entries stay ``None``.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Iterator, Optional, Tuple

import jax

log = logging.getLogger("repro.degrade")

#: engine step-downs, leanest-retained-set direction
ENGINE_LADDER = {"mesp_pallas": "mesp", "mesp": "mesp_seq"}


class LadderExhausted(RuntimeError):
    """No rung left: the spec is already at the floor of the ladder."""


def _import_memsim():
    """``benchmarks/`` lives at the repo root (a namespace package next to
    ``src/``), so it is importable when launched from the repo but not from
    an arbitrary cwd — fall back to the root inferred from this file."""
    try:
        from benchmarks import memsim
        return memsim
    except ImportError:
        import os
        import sys
        here = os.path.abspath(__file__)   # <root>/src/repro/runtime/...
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(here))))
        if not os.path.isdir(os.path.join(root, "benchmarks")):
            raise
        sys.path.insert(0, root)
        try:
            from benchmarks import memsim
            return memsim
        finally:
            sys.path.remove(root)


def predicted_peak_mb(spec) -> Optional[float]:
    """Analytical peak (MB) for a spec via ``benchmarks/memsim``'s retention
    models. None when memsim (or the arch entry) is unavailable — callers
    treat that as "cannot validate", not as an error, so the ladder still
    functions in stripped deployments."""
    try:
        memsim = _import_memsim()
    except ImportError:
        return None
    try:
        from repro.core.quant import weights_format
        b = memsim.simulate(spec.arch, spec.engine, spec.seq,
                            batch=spec.batch,
                            weights_fmt=weights_format(spec.quantize),
                            reduced=getattr(spec, "reduced", False))
        return b.total_mb
    except Exception as e:  # unknown arch / engine without memsim hook
        log.debug("memsim validation unavailable for %s: %s", spec.engine, e)
        return None


def _flatten_paths(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def carry_opt_state(opt_state, old_params, new_params):
    """Re-map an optimizer state dict onto a transformed param tree.

    Scalars (``step``) copy through; tree-valued entries (momentum ``m``,
    Adam ``m``/``v``) are rebuilt on ``new_params``'s structure with each
    leaf taken from the same parameter path in the old tree, or ``None``
    where the path is new (e.g. the ``{"q","scale"}`` leaves the int8 rung
    introduces — frozen slots carry no state anyway)."""
    if not isinstance(opt_state, dict):
        return opt_state
    out = {}
    for key, val in opt_state.items():
        if not isinstance(val, (dict, list, tuple)):
            out[key] = val
            continue
        old = _flatten_paths(val)
        out[key] = jax.tree_util.tree_map_with_path(
            lambda path, _leaf: old.get(jax.tree_util.keystr(path)),
            new_params)
    return out


class WatermarkTrigger:
    """Proactive memory-pressure signal from measured watermarks.

    The OOM-exception path reacts *after* the allocator fails; with telemetry
    on, the resilient loop also samples the live watermark
    (``telemetry.memwatch``) after each step and feeds it here.  Once the
    measured residency stays above ``threshold × budget_mb`` for
    ``consecutive`` samples, :meth:`observe` returns True and the loop walks
    the same ladder *before* the device actually OOMs.  ``consecutive`` is
    the hysteresis: one transient spike (a checkpoint buffer, a fresh jit)
    must not cost a rung.
    """

    def __init__(self, budget_mb: float, *, threshold: float = 0.9,
                 consecutive: int = 2):
        if budget_mb <= 0:
            raise ValueError(f"budget_mb must be > 0, got {budget_mb}")
        self.budget_mb = budget_mb
        self.threshold = threshold
        self.consecutive = consecutive
        self.trips = 0
        self._over_streak = 0

    @property
    def limit_mb(self) -> float:
        return self.threshold * self.budget_mb

    def observe(self, measured_mb: float) -> bool:
        """Feed one watermark sample; True = degrade now."""
        if measured_mb >= self.limit_mb:
            self._over_streak += 1
        else:
            self._over_streak = 0
        if self._over_streak >= self.consecutive:
            self.trips += 1
            self._over_streak = 0   # re-arm after the rung lands
            return True
        return False

    def reset(self) -> None:
        self._over_streak = 0


class DegradationLadder:
    """Yields validated degraded specs for a spec under memory pressure."""

    def __init__(self, *, min_batch: int = 1, min_seq: int = 32,
                 require_memsim_improvement: bool = True):
        self.min_batch = min_batch
        self.min_seq = min_seq
        self.require_memsim_improvement = require_memsim_improvement
        self.applied: list = []     # rung names, in application order

    # ------------------------------------------------------------ raw rungs
    def _raw_candidates(self, spec) -> Iterator[Tuple[object, str]]:
        if spec.batch > self.min_batch:
            yield (dataclasses.replace(spec, batch=spec.batch // 2),
                   "halve_batch")
        nxt = ENGINE_LADDER.get(spec.engine)
        if nxt is not None:
            yield dataclasses.replace(spec, engine=nxt), f"engine_{nxt}"
        if spec.quantize == "none":
            yield (dataclasses.replace(spec, quantize="int8"),
                   "quantize_int8")
        if spec.quantize == "int8":
            # one notch at a time: the packed rung halves resident W0 again
            # (quantize_params re-quantizes the already-int8 tree in place)
            yield (dataclasses.replace(spec, quantize="int4"),
                   "quantize_int4")
        if spec.seq > self.min_seq:
            yield (dataclasses.replace(spec, seq=max(self.min_seq,
                                                     spec.seq // 2)),
                   "truncate_seq")

    # ------------------------------------------------------------ validated
    def candidates(self, spec) -> Iterator[Tuple[object, str]]:
        """Registry- and memsim-validated rungs, in ladder order. The caller
        (Trainer) applies the first one whose step also builds."""
        base_peak = predicted_peak_mb(spec)
        any_yielded = False
        for cand, rung in self._raw_candidates(spec):
            try:
                cand.validate()
            except Exception as e:
                log.debug("rung %s rejected by registry: %s", rung, e)
                continue
            peak = predicted_peak_mb(cand)
            if (self.require_memsim_improvement and base_peak is not None
                    and peak is not None and peak > base_peak + 1e-6):
                log.debug("rung %s rejected by memsim: %.1f MB > %.1f MB",
                          rung, peak, base_peak)
                continue
            any_yielded = True
            yield cand, rung
        if not any_yielded:
            raise LadderExhausted(
                f"degradation ladder exhausted at engine={spec.engine!r} "
                f"batch={spec.batch} seq={spec.seq} "
                f"quantize={spec.quantize!r}")

    def record(self, rung: str) -> None:
        self.applied.append(rung)
