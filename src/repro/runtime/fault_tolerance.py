"""Fault tolerance runtime: restartable training driver + straggler policy.

At 1000+ node scale the failure model is: (a) whole-job preemption/crash —
handled by atomic checkpoints + auto-resume; (b) single-node hangs /
stragglers — handled by a per-step watchdog that skips the step and raises a
restart signal after ``max_step_time`` (on real multi-host TPU this pairs
with the platform's slice-rescheduling; here the policy layer is exercised by
injected-failure tests); (c) data-loss on restart — prevented by checkpointing
the data-iterator state.

``run_resilient`` is the generic driver used by launch/train.py and the
fault-injection tests.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.checkpoint import Checkpointer

log = logging.getLogger("repro.ft")


@dataclass
class StepResult:
    step: int
    loss: float
    seconds: float
    retried: bool = False


class StragglerPolicy:
    """EWMA step-time tracker; flags steps slower than ``factor``× the mean.

    On real hardware a flagged step triggers (1) collective-timeout logging,
    (2) optional step skip for async-capable optimizers, (3) a restart signal
    if ``consecutive_limit`` is exceeded (the node is presumed sick).
    """

    def __init__(self, factor: float = 3.0, consecutive_limit: int = 3,
                 alpha: float = 0.1):
        self.factor = factor
        self.limit = consecutive_limit
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.slow_streak = 0

    def observe(self, seconds: float) -> str:
        """Returns 'ok' | 'slow' | 'restart'."""
        if self.mean is None:
            self.mean = seconds
            return "ok"
        verdict = "ok"
        if seconds > self.factor * self.mean:
            self.slow_streak += 1
            verdict = "restart" if self.slow_streak >= self.limit else "slow"
        else:
            self.slow_streak = 0
        # slow steps don't poison the EWMA baseline
        if verdict == "ok":
            self.mean = (1 - self.alpha) * self.mean + self.alpha * seconds
        return verdict


class RestartRequired(RuntimeError):
    pass


def run_resilient(step_fn: Callable[[Any, Any, dict], tuple],
                  init_state: Callable[[], tuple],
                  batch_iter,
                  ckpt: Checkpointer,
                  total_steps: int,
                  *,
                  max_retries: int = 3,
                  straggler: Optional[StragglerPolicy] = None,
                  on_step: Optional[Callable[[StepResult], None]] = None):
    """Run ``total_steps`` of ``step_fn``, resuming from the latest checkpoint.

    step_fn(params, opt_state, batch) -> (params, opt_state, loss)
    init_state() -> (params, opt_state)

    Transient step failures (raised exceptions) are retried up to
    ``max_retries`` from the last checkpoint — the injected-failure test
    exercises this path end-to-end.
    """
    straggler = straggler or StragglerPolicy()
    retries = 0

    def _restore():
        params, opt_state = init_state()
        restored = ckpt.restore_latest(params, opt_state)
        if restored is not None:
            log.info("resuming from step %d", restored["step"])
            if restored["data_state"]:
                batch_iter.state = type(batch_iter.state).from_dict(
                    restored["data_state"])
            return restored["step"], restored["params"], restored["opt_state"]
        return 0, params, opt_state

    step, params, opt_state = _restore()
    results = []
    while step < total_steps:
        batch = next(batch_iter)
        t0 = time.monotonic()
        try:
            params, opt_state, loss = step_fn(params, opt_state, batch)
        except Exception as e:  # injected failure / device error
            retries += 1
            log.warning("step %d failed (%s); retry %d/%d from checkpoint",
                        step, e, retries, max_retries)
            if retries > max_retries:
                raise
            step, params, opt_state = _restore()
            continue
        dt = time.monotonic() - t0
        verdict = straggler.observe(dt)
        if verdict == "restart":
            raise RestartRequired(
                f"step {step}: {dt:.1f}s ≥ {straggler.factor}× EWMA "
                f"for {straggler.limit} consecutive steps")
        step += 1
        res = StepResult(step, float(loss), dt, retried=retries > 0)
        results.append(res)
        if on_step:
            on_step(res)
        ckpt.maybe_save(step, params, opt_state,
                        data_state=batch_iter.state.to_dict())
    return params, opt_state, results
