"""Fault-tolerant runtime: the supervised ResilientLoop + straggler policy.

On-device (and at 1000+ node scale) the failure model is: (a) whole-job
preemption/crash — handled by atomic checkpoints + auto-resume; (b) memory
pressure / ``RESOURCE_EXHAUSTED`` — handled by the degradation ladder
(``runtime/degrade.py``) before falling back to retry; (c) numerical
anomalies (NaN loss, gradient spikes) — handled by the step guard
(``runtime/guard.py``) with a bounded skip-and-rewind budget; (d) hangs /
stragglers — a per-step watchdog whose ``restart`` verdict triggers a
supervised restore-from-checkpoint (bounded by ``restart_budget``);
(e) data-loss on restart — prevented by checkpointing the data-iterator
state.

:class:`ResilientLoop` is the supervisor: it owns the step/retry state
machine, classifies failures (OOM vs transient), applies exponential
backoff, **resets the retry budget after every successful step** (one
transient early plus another much later must not kill a long run), counts
every fault into :class:`FaultCounters`, and always force-saves a final
checkpoint on exit so a completed run is resumable/servable even when
``total_steps % interval != 0``.

``run_resilient`` remains as the thin functional wrapper used by older
call sites and tests; it runs the same loop with ``restart_budget=0``
(straggler restarts raise, the historical contract).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.checkpoint import Checkpointer
from repro.runtime.faults import is_oom_error

log = logging.getLogger("repro.ft")


@dataclass
class StepResult:
    step: int
    loss: float
    seconds: float
    retried: bool = False


@dataclass
class FaultCounters:
    """Per-fault accounting surfaced in ``TrainResult`` and the chaos
    benchmark's ``BENCH_resilience.json``."""
    step_failures: int = 0        # generic exceptions (incl. crashes)
    oom_events: int = 0           # RESOURCE_EXHAUSTED-class failures
    degradations: int = 0         # ladder rungs applied
    watermark_triggers: int = 0   # proactive degrades from measured pressure
    guard_skips: int = 0          # anomalous steps rejected + rewound
    straggler_restarts: int = 0   # watchdog-triggered supervised restarts
    ckpt_quarantines: int = 0     # corrupt checkpoints quarantined
    steps_replayed: int = 0       # steps re-run after restore rewinds
    backoff_seconds: float = 0.0  # total time spent backing off
    injected: dict = field(default_factory=dict)  # {kind: fired} from plan

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def total_faults(self) -> int:
        return (self.step_failures + self.oom_events + self.guard_skips
                + self.straggler_restarts)


class StragglerPolicy:
    """EWMA step-time tracker; flags steps slower than ``factor``× the mean.

    The first ``warmup`` observations are discarded from the baseline (the
    jit-compile step would otherwise seed the EWMA with a wildly unhistoric
    mean). On real hardware a flagged step triggers (1) collective-timeout
    logging, (2) optional step skip for async-capable optimizers, (3) a
    restart signal if ``consecutive_limit`` is exceeded (the node is
    presumed sick).
    """

    def __init__(self, factor: float = 3.0, consecutive_limit: int = 3,
                 alpha: float = 0.1, warmup: int = 1):
        self.factor = factor
        self.limit = consecutive_limit
        self.alpha = alpha
        self.warmup = warmup
        self._seen = 0
        self.mean: Optional[float] = None
        self.slow_streak = 0

    def reset(self) -> None:
        """Re-seed the baseline (after a restart or a re-jitted step)."""
        self._seen = 0
        self.mean = None
        self.slow_streak = 0

    def observe(self, seconds: float) -> str:
        """Returns 'ok' | 'slow' | 'restart'."""
        self._seen += 1
        if self._seen <= self.warmup:
            return "ok"                      # compile step: not a baseline
        if self.mean is None:
            self.mean = seconds
            return "ok"
        verdict = "ok"
        if seconds > self.factor * self.mean:
            self.slow_streak += 1
            verdict = "restart" if self.slow_streak >= self.limit else "slow"
        else:
            self.slow_streak = 0
        # slow steps don't poison the EWMA baseline
        if verdict == "ok":
            self.mean = (1 - self.alpha) * self.mean + self.alpha * seconds
        return verdict


class RestartRequired(RuntimeError):
    pass


class ResilientLoop:
    """Supervised training-step driver.

    step_fn(params, opt_state, batch) -> (params, opt_state, loss)
    init_state() -> (params, opt_state)

    Pluggable hooks (all optional) let the Trainer facade wire in the full
    resilience stack without this module importing any of it eagerly:

    * ``injector``   — :class:`~repro.runtime.faults.FaultInjector`; its
      ``before_step`` runs inside the try block (raising kinds land in the
      failure handler) and ``after_step`` may replace the loss.
    * ``guard``      — :class:`~repro.runtime.guard.StepGuard`; a ``reject``
      verdict rewinds the step (new params/opt-state discarded, batch
      skipped).
    * ``on_oom(loop)`` — degradation hook. May swap ``loop.step_fn`` /
      ``loop.batch_iter`` and return transformed ``(params, opt_state)`` to
      retry the same step under a cheaper spec; ``None`` falls through to
      the ordinary retry path.
    * ``restore_fn(loop)`` — replaces the default restore (the Trainer uses
      this to rebuild engine/iterator from the spec recorded in the
      checkpoint manifest). Must return ``(step, params, opt_state)`` and
      update ``loop.batch_iter``/``loop.step_fn`` as needed.
    * ``extra_fn()`` — dict merged into every checkpoint manifest (the
      Trainer records the live spec so restores are self-describing).
    * ``telemetry`` — :class:`repro.telemetry.Telemetry`; when enabled the
      loop emits typed step/fault/checkpoint/watermark events, wraps
      data-fetch/step/checkpoint/restore in trace spans and keeps
      ``train.*`` metrics. Disabled (the default) the hot path pays one
      flag check and nothing else — same jitted step object, no span or
      record allocation (asserted by tests/test_telemetry.py).
    * ``memwatch`` — :class:`repro.telemetry.MemoryWatermark`; sampled after
      every successful step.
    * ``pressure`` — :class:`repro.runtime.degrade.WatermarkTrigger`; fed
      the watermark samples, and when it trips the loop walks the same
      ``on_oom`` ladder *before* the allocator actually fails.
    """

    def __init__(self, step_fn: Callable[[Any, Any, dict], tuple],
                 init_state: Callable[[], tuple],
                 batch_iter,
                 ckpt: Checkpointer,
                 total_steps: int,
                 *,
                 max_retries: int = 3,
                 restart_budget: int = 0,
                 backoff_base: float = 0.05,
                 backoff_max: float = 30.0,
                 straggler: Optional[StragglerPolicy] = None,
                 guard=None,
                 injector=None,
                 on_step: Optional[Callable[[StepResult], None]] = None,
                 on_oom: Optional[Callable] = None,
                 restore_fn: Optional[Callable] = None,
                 extra_fn: Optional[Callable[[], dict]] = None,
                 telemetry=None,
                 memwatch=None,
                 pressure=None):
        self.step_fn = step_fn
        self.init_state = init_state
        self.batch_iter = batch_iter
        self.ckpt = ckpt
        self.total_steps = total_steps
        self.max_retries = max_retries
        self.restart_budget = restart_budget
        self.backoff_base = backoff_base
        self.backoff_max = backoff_max
        self.straggler = straggler or StragglerPolicy()
        self.guard = guard
        self.injector = injector
        self.on_step = on_step
        self.on_oom = on_oom
        self.restore_fn = restore_fn
        self.extra_fn = extra_fn
        if telemetry is None:
            from repro.telemetry import DISABLED
            telemetry = DISABLED
        self.telemetry = telemetry
        self.memwatch = memwatch
        self.pressure = pressure
        #: why the current on_oom invocation happened ("oom" | "watermark");
        #: read by the Trainer's degrade hook to tag its DegradeEvent
        self.degrade_trigger = "oom"

        self.counters = FaultCounters()
        self.step = 0
        self.params = None
        self.opt_state = None
        self._consecutive_failures = 0
        self._last_saved: Optional[int] = None
        # snapshot of the iterator's initial position so a restore with no
        # checkpoint replays the exact token stream from the start
        state = getattr(batch_iter, "state", None)
        self._initial_data_state = (dataclasses.replace(state)
                                    if dataclasses.is_dataclass(state)
                                    else None)

    # -------------------------------------------------------------- restore
    def _data_state_dict(self) -> Optional[dict]:
        state = getattr(self.batch_iter, "state", None)
        return state.to_dict() if state is not None else None

    def _restore(self):
        with self.telemetry.span("restore"):
            return self._restore_inner()

    def _restore_inner(self):
        self.straggler.reset()
        t0 = time.monotonic()
        if self.restore_fn is not None:
            step, params, opt_state = self.restore_fn(self)
        else:
            params, opt_state = self.init_state()
            restored = self.ckpt.restore_latest(params, opt_state)
            if restored is not None:
                log.info("resuming from step %d", restored["step"])
                if restored["data_state"]:
                    self.batch_iter.state = type(
                        self.batch_iter.state).from_dict(
                        restored["data_state"])
                step, params, opt_state = (restored["step"],
                                           restored["params"],
                                           restored["opt_state"])
            else:
                step = 0
                if self._initial_data_state is not None:
                    self.batch_iter.state = dataclasses.replace(
                        self._initial_data_state)
        if step < self.step:
            self.counters.steps_replayed += self.step - step
        prev_quar = self.counters.ckpt_quarantines
        self.counters.ckpt_quarantines = len(
            getattr(self.ckpt, "quarantined", ()))
        tel = self.telemetry
        if tel.enabled:
            from repro.telemetry import CheckpointEvent
            tel.emit(CheckpointEvent(action="restore", step=step,
                                     seconds=time.monotonic() - t0,
                                     path=self.ckpt.directory))
            for _ in range(self.counters.ckpt_quarantines - prev_quar):
                tel.emit(CheckpointEvent(action="quarantine", step=step,
                                         path=self.ckpt.directory))
            tel.registry.counter("ckpt.restores").inc()
        return step, params, opt_state

    # ----------------------------------------------------------------- save
    def _save_now(self) -> None:
        t0 = time.monotonic()
        with self.telemetry.span("checkpoint"):
            self.ckpt.save(self.step, self.params, self.opt_state,
                           data_state=self._data_state_dict(),
                           extra=self.extra_fn() if self.extra_fn else None)
        self._last_saved = self.step
        tel = self.telemetry
        if tel.enabled:
            from repro.telemetry import CheckpointEvent
            tel.emit(CheckpointEvent(action="save", step=self.step,
                                     seconds=time.monotonic() - t0,
                                     path=self.ckpt.directory))
            tel.registry.counter("ckpt.saves").inc()

    # -------------------------------------------------------------- failure
    def _handle_failure(self, e: BaseException) -> None:
        oom = is_oom_error(e)
        tel = self.telemetry
        if tel.enabled:
            from repro.telemetry import FaultEvent as TelFault
            tel.emit(TelFault(step=self.step,
                              fault="oom" if oom else "exception",
                              injected=type(e).__name__.startswith("Injected"),
                              source="loop", error=str(e)))
            tel.registry.counter(
                "faults.oom" if oom else "faults.exception").inc()
        if oom:
            self.counters.oom_events += 1
            log.warning("step %d hit memory pressure: %s", self.step, e)
            if self.on_oom is not None:
                swapped = self.on_oom(self)
                if swapped is not None:
                    self.params, self.opt_state = swapped
                    self.counters.degradations += 1
                    self.straggler.reset()   # next step re-jits: not slow
                    # checkpoint the degraded state immediately so any later
                    # restore reconstitutes the post-degradation program
                    self._save_now()
                    return
        else:
            self.counters.step_failures += 1
        self._consecutive_failures += 1
        log.warning("step %d failed (%s); retry %d/%d from checkpoint",
                    self.step, e, self._consecutive_failures,
                    self.max_retries)
        if self._consecutive_failures > self.max_retries:
            raise
        delay = min(self.backoff_max,
                    self.backoff_base * (2 ** (self._consecutive_failures
                                               - 1)))
        if delay > 0:
            self.counters.backoff_seconds += delay
            time.sleep(delay)
        self.step, self.params, self.opt_state = self._restore()

    # ---------------------------------------------------------- memwatch
    def _sample_watermark(self) -> None:
        """Post-step watermark sample: metrics/event, then pressure check."""
        m = self.memwatch.sample()
        pred = self.memwatch.predicted_mb
        tel = self.telemetry
        if tel.enabled:
            from repro.telemetry import WatermarkEvent
            tel.registry.gauge("mem.measured_mb").set(m["measured_mb"])
            tel.registry.gauge("mem.peak_mb").set(m["peak_mb"])
            tel.emit(WatermarkEvent(
                step=self.step, measured_mb=round(m["measured_mb"], 3),
                peak_mb=round(m["peak_mb"], 3),
                predicted_mb=round(pred or 0.0, 3),
                ratio=round(m["peak_mb"] / pred, 4) if pred else 0.0,
                source=m["source"]))
        if self.pressure is not None \
                and self.pressure.observe(m["measured_mb"]):
            self._degrade_for_pressure(m["measured_mb"])

    def _degrade_for_pressure(self, measured_mb: float) -> None:
        """Walk the on_oom ladder proactively, before the allocator fails."""
        if self.on_oom is None:
            self.pressure = None
            return
        self.counters.watermark_triggers += 1
        log.warning("watermark pressure: %.1f MB >= %.1f MB limit at step "
                    "%d; degrading proactively", measured_mb,
                    self.pressure.limit_mb, self.step)
        self.degrade_trigger = "watermark"
        try:
            swapped = self.on_oom(self)
        finally:
            self.degrade_trigger = "oom"
        if swapped is not None:
            self.params, self.opt_state = swapped
            self.counters.degradations += 1
            self.straggler.reset()
            self._save_now()
        else:
            # ladder exhausted: nothing cheaper exists, stop re-checking
            log.warning("watermark pressure with no rung left; trigger "
                        "disabled for the rest of the run")
            self.pressure = None

    # ------------------------------------------------------------------ run
    def run(self):
        from repro.runtime.guard import update_norm as _update_norm

        self.step, self.params, self.opt_state = self._restore()
        tel = self.telemetry
        results = []
        while self.step < self.total_steps:
            t0 = time.monotonic()
            try:
                if self.injector is not None:
                    self.injector.before_step(self.step)
                # one flag check on the hot path: the disabled branch runs
                # the exact pre-telemetry code, no span/context allocation
                if tel.enabled:
                    with tel.span("data_fetch"):
                        batch = next(self.batch_iter)
                    with tel.span("step"):
                        new_params, new_opt, loss = self.step_fn(
                            self.params, self.opt_state, batch)
                else:
                    batch = next(self.batch_iter)
                    new_params, new_opt, loss = self.step_fn(
                        self.params, self.opt_state, batch)
                if self.injector is not None:
                    loss = self.injector.after_step(self.step, loss)
                lossf = float(loss)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                self._handle_failure(e)
                continue
            if self.guard is not None:
                unorm = (_update_norm(self.params, new_params)
                         if self.guard.track_update_norm else None)
                if self.guard.observe(lossf, update_norm=unorm,
                                      step=self.step) == "reject":
                    self.counters.guard_skips += 1
                    continue      # rewind: update discarded, batch skipped
            dt = time.monotonic() - t0
            verdict = self.straggler.observe(dt)
            if verdict == "restart":
                self.counters.straggler_restarts += 1
                if self.counters.straggler_restarts > self.restart_budget:
                    raise RestartRequired(
                        f"step {self.step}: {dt:.1f}s >= "
                        f"{self.straggler.factor}x EWMA for "
                        f"{self.straggler.limit} consecutive steps")
                log.warning("straggler watchdog: supervised restart %d/%d "
                            "at step %d (%.1fs step)",
                            self.counters.straggler_restarts,
                            self.restart_budget, self.step, dt)
                self.step, self.params, self.opt_state = self._restore()
                continue
            elif verdict == "slow":
                log.warning("step %d slow: %.2fs vs EWMA %.2fs",
                            self.step, dt, self.straggler.mean or 0.0)
            self.params, self.opt_state = new_params, new_opt
            self._consecutive_failures = 0    # budget resets on success
            self.step += 1
            res = StepResult(self.step, lossf, dt,
                             retried=self.counters.total_faults > 0)
            results.append(res)
            if tel.enabled:
                from repro.telemetry import StepEvent
                tel.emit(StepEvent(step=self.step, loss=lossf, seconds=dt))
                tel.registry.counter("train.steps").inc()
                tel.registry.gauge("train.loss").set(lossf)
                tel.registry.histogram("train.step_seconds").record(dt)
            if self.memwatch is not None:
                self._sample_watermark()
            if self.on_step:
                self.on_step(res)
            saved = self.ckpt.maybe_save(
                self.step, self.params, self.opt_state,
                data_state=self._data_state_dict(),
                extra=self.extra_fn() if self.extra_fn else None)
            if saved:
                self._last_saved = self.step
                if tel.enabled:
                    from repro.telemetry import CheckpointEvent
                    tel.emit(CheckpointEvent(action="save", step=self.step,
                                             path=self.ckpt.directory))
                    tel.registry.counter("ckpt.saves").inc()
        # forced final save: a completed run is always resumable/servable
        # from its last step, even when total_steps % interval != 0
        if self.step > 0 and self._last_saved != self.step:
            self._save_now()
        if self.injector is not None:
            self.counters.injected = self.injector.summary()
        self.counters.ckpt_quarantines = len(
            getattr(self.ckpt, "quarantined", ()))
        return self.params, self.opt_state, results, self.counters


def run_resilient(step_fn: Callable[[Any, Any, dict], tuple],
                  init_state: Callable[[], tuple],
                  batch_iter,
                  ckpt: Checkpointer,
                  total_steps: int,
                  *,
                  max_retries: int = 3,
                  straggler: Optional[StragglerPolicy] = None,
                  on_step: Optional[Callable[[StepResult], None]] = None):
    """Functional wrapper over :class:`ResilientLoop` (historical API).

    Keeps the original contract: straggler ``restart`` verdicts raise
    :class:`RestartRequired` (``restart_budget=0``) and the return value is
    ``(params, opt_state, results)`` without counters.
    """
    loop = ResilientLoop(step_fn, init_state, batch_iter, ckpt, total_steps,
                         max_retries=max_retries, restart_budget=0,
                         straggler=straggler, on_step=on_step)
    params, opt_state, results, _ = loop.run()
    return params, opt_state, results
