"""Elastic scaling: re-shard a training job onto a different mesh.

Checkpoints are mesh-agnostic (logical layout), so elasticity reduces to:
(1) pick the new mesh from the surviving device set, (2) rebuild shardings
from the same logical PartitionSpecs, (3) ``jax.device_put`` the restored
arrays. ``reshard_tree`` also serves live resharding (no checkpoint round
trip) when the runtime shrinks/grows within a job.

The data pipeline re-slices by the new (host_index, host_count), and the
global batch is kept constant by scaling per-host batch — the optimizer
trajectory is unchanged across a resize (tested in tests/test_elastic.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh_from_devices(devices: Sequence[jax.Device],
                           model_parallel: int,
                           pods: int = 1) -> Mesh:
    """Build the largest (pod, data, model) mesh from a surviving device set.

    Axis naming matches ``launch/sharding.py``'s expectations: ``("data",
    "model")`` for a single pod, ``("pod", "data", "model")`` when ``pods >
    1``. Raises ``ValueError`` (survives ``python -O``, unlike an assert)
    when the survivor count is not divisible by ``model_parallel × pods`` —
    the caller must drop stragglers to a divisible count first.
    """
    n = len(devices)
    if model_parallel < 1 or pods < 1:
        raise ValueError(f"model_parallel={model_parallel} and pods={pods} "
                         "must be >= 1")
    if n == 0 or n % (model_parallel * pods) != 0:
        raise ValueError(
            f"{n} surviving devices not divisible by "
            f"model={model_parallel} x pods={pods}; shrink to a divisible "
            f"survivor count before resizing")
    data = n // (model_parallel * pods)
    arr = np.asarray(devices[:pods * data * model_parallel]).reshape(
        pods, data, model_parallel)
    if pods == 1:
        return Mesh(arr[0], ("data", "model"))
    return Mesh(arr, ("pod", "data", "model"))


def reshard_tree(tree, mesh: Mesh, specs):
    """device_put every leaf onto (mesh, spec) — the elastic resize core."""
    def put(leaf, spec):
        if leaf is None:
            return None
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree, specs,
                                  is_leaf=lambda x: x is None)


def rebalance_batch(global_batch: int, old_hosts: int, new_hosts: int) -> int:
    """Per-host batch after a resize, keeping the global batch invariant."""
    if new_hosts < 1 or global_batch % new_hosts != 0:
        raise ValueError(
            f"global batch {global_batch} cannot be kept invariant over "
            f"{new_hosts} hosts — choose a divisor count")
    return global_batch // new_hosts
