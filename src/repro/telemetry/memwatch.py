"""Memory watermark monitor: measured device residency vs memsim prediction.

Samples around step boundaries (the resilient loop calls :meth:`sample` after
each step) and keeps a running peak.  Two sources:

* ``device_stats`` — ``jax.local_devices()[*].memory_stats()`` where the
  backend exposes allocator stats (TPU/GPU).  ``peak_bytes_in_use`` is used
  when present, so in-step temporaries are included.
* ``live_arrays`` — CPU fallback (``memory_stats()`` returns ``None`` there):
  sums ``x.nbytes`` over ``jax.live_arrays()``.  This counts *resident*
  arrays (params, optimizer state, caches) and is a lower bound on the true
  peak — in-jit temporaries are invisible — which is why the
  measured/predicted ratio gate is annotate-only on CPU.

``predicted_mb`` is set by the trainer from ``runtime.degrade``'s memsim
bridge for the *live* spec and refreshed after every degradation rung, so the
paper's peak-memory claim is cross-checked continuously, not just analytically.
"""
from __future__ import annotations

from typing import Optional


def _device_stats_mb() -> Optional[dict]:
    """Summed allocator stats across local devices, or None (CPU)."""
    import jax
    in_use = 0
    peak = 0
    saw_peak = False
    for dev in jax.local_devices():
        stats = dev.memory_stats()
        if stats is None:
            return None
        in_use += stats.get("bytes_in_use", 0)
        if "peak_bytes_in_use" in stats:
            peak += stats["peak_bytes_in_use"]
            saw_peak = True
    return {"measured_mb": in_use / 2**20,
            "hw_peak_mb": (peak / 2**20) if saw_peak else None}


def _live_arrays_mb() -> float:
    import jax
    return sum(x.nbytes for x in jax.live_arrays()) / 2**20


class MemoryWatermark:
    """Running peak of measured device memory, with a memsim cross-check."""

    def __init__(self, source: str = "auto"):
        if source not in ("auto", "device_stats", "live_arrays"):
            raise ValueError(f"unknown memwatch source {source!r}")
        self._requested = source
        self.source = source          # resolved on first sample when "auto"
        self.peak_mb = 0.0
        self.last_mb = 0.0
        self.samples = 0
        self.predicted_mb = 0.0       # memsim peak for the live spec

    def sample(self) -> dict:
        """Measure now; update the running peak; return the sample dict."""
        measured = None
        if self._requested in ("auto", "device_stats"):
            stats = _device_stats_mb()
            if stats is not None:
                self.source = "device_stats"
                measured = stats["measured_mb"]
                hw_peak = stats["hw_peak_mb"]
                if hw_peak is not None and hw_peak > self.peak_mb:
                    self.peak_mb = hw_peak
            elif self._requested == "device_stats":
                raise RuntimeError("device memory_stats() unavailable on "
                                   "this backend; use source='live_arrays'")
        if measured is None:
            self.source = "live_arrays"
            measured = _live_arrays_mb()
        self.last_mb = measured
        if measured > self.peak_mb:
            self.peak_mb = measured
        self.samples += 1
        return {"measured_mb": measured, "peak_mb": self.peak_mb,
                "source": self.source}

    def compare(self, predicted_mb: Optional[float] = None) -> dict:
        """Measured peak vs memsim predicted peak (the paper's 49% claim as
        a continuously-measured quantity)."""
        pred = self.predicted_mb if predicted_mb is None else predicted_mb
        ratio = (self.peak_mb / pred) if pred else 0.0
        return {"measured_peak_mb": round(self.peak_mb, 3),
                "predicted_peak_mb": round(pred, 3),
                "ratio": round(ratio, 4),
                "source": self.source, "samples": self.samples}
