"""Metric primitives: counters, gauges, histograms, and the registry.

This module is dependency-free (no jax, no repro imports) so every layer of
the stack — ``kernels/autotune.py`` included — can keep counters here without
import cycles.

Two organizing ideas:

* A :class:`CounterGroup` is an *ordered, dict-compatible* bundle of counters
  under one namespace ("serve", "store", "pages", "autotune").  It replaces
  the private ``self.counters = {...}`` dicts that used to live on
  ``AdapterStore`` / ``PagedKVAllocator`` / ``ContinuousBatcher`` — existing
  call sites (``dict(x.counters)``, ``c.update({k: 0 for k in c})``,
  ``c["admitted"] += 1``) keep working unchanged.

* A :class:`MetricRegistry` unifies groups plus free-standing namespaced
  counters/gauges/histograms into one flat ``snapshot()`` — e.g.
  ``{"serve.admitted": 3, "store.hits": 7, "guard.loss_ewma": 2.1}``.
"""
from __future__ import annotations

from collections.abc import MutableMapping
from typing import Dict, Iterable, Iterator, List, Optional


class Counter:
    """Monotonic-by-convention integer counter (reset via ``value = 0``)."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value

    def __repr__(self):  # pragma: no cover - debug aid
        return f"Counter({self.value})"


class Gauge:
    """Last-write-wins scalar (EWMAs, watermarks, queue depths)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value

    def __repr__(self):  # pragma: no cover - debug aid
        return f"Gauge({self.value})"


class Histogram:
    """Bounded-memory streaming summary: count/sum/min/max plus log2 buckets.

    ``record`` is O(1) and allocation-free after construction; ``summary()``
    is what lands in snapshots and the JSONL run footer.
    """

    __slots__ = ("count", "total", "min", "max", "_buckets")

    #: bucket upper bounds (seconds-ish scale); last bucket is +inf
    BOUNDS = (0.001, 0.004, 0.016, 0.064, 0.25, 1.0, 4.0, 16.0, 64.0)

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._buckets = [0] * (len(self.BOUNDS) + 1)

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for i, bound in enumerate(self.BOUNDS):
            if v <= bound:
                self._buckets[i] += 1
                return
        self._buckets[-1] += 1

    def summary(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "mean": None}
        return {"count": self.count, "sum": self.total,
                "min": self.min, "max": self.max,
                "mean": self.total / self.count,
                "buckets": dict(zip([str(b) for b in self.BOUNDS] + ["inf"],
                                    self._buckets))}


class CounterGroup(MutableMapping):
    """Ordered dict-compatible view over a namespace of :class:`Counter`.

    Behaves like the plain ``dict`` counters it replaces — iteration order is
    insertion order, values are ints, ``update``/``dict()``/``+=`` all work —
    while the underlying Counter objects can be shared with a registry.
    """

    __slots__ = ("name", "_counters")

    def __init__(self, name: str, keys: Iterable[str] = ()):
        self.name = name
        self._counters: Dict[str, Counter] = {}
        for k in keys:
            self._counters[k] = Counter()

    def counter(self, key: str) -> Counter:
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    # --- MutableMapping protocol (int-valued, like the old plain dicts) ----
    def __getitem__(self, key: str) -> int:
        return self._counters[key].value

    def __setitem__(self, key: str, value: int) -> None:
        self.counter(key).value = value

    def __delitem__(self, key: str) -> None:
        del self._counters[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._counters)

    def __len__(self) -> int:
        return len(self._counters)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"CounterGroup({self.name!r}, {dict(self)})"

    def namespaced(self) -> Dict[str, int]:
        return {f"{self.name}.{k}": c.value for k, c in self._counters.items()}


class MetricRegistry:
    """One flat namespace of groups + free-standing metrics.

    Names are dotted (``"train.steps"``, ``"guard.loss_ewma"``); groups
    registered via :meth:`register_group` contribute ``<group>.<key>`` rows
    to :meth:`snapshot`.
    """

    def __init__(self):
        self._groups: Dict[str, CounterGroup] = {}
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # --- groups ------------------------------------------------------------
    def register_group(self, group: CounterGroup) -> CounterGroup:
        """Adopt an externally-created group (idempotent; name keyed)."""
        self._groups[group.name] = group
        return group

    def group(self, name: str, keys: Iterable[str] = ()) -> CounterGroup:
        g = self._groups.get(name)
        if g is None:
            g = self._groups[name] = CounterGroup(name, keys)
        else:
            for k in keys:
                g.counter(k)
        return g

    # --- free-standing metrics --------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram()
        return h

    # --- snapshot ----------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Flat ``{dotted_name: value}``; histograms appear as summaries."""
        out: Dict[str, object] = {}
        for g in self._groups.values():
            out.update(g.namespaced())
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._histograms.items():
            out[name] = h.summary()
        return out
