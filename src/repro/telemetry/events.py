"""Typed telemetry events, the JSONL wire schema, and sinks.

Wire format — one JSON object per line::

    {"v": 1, "ts": 1699999999.123, "kind": "step", "seq": 7,
     "worker": 0, ...kind-specific fields...}

``v`` is :data:`SCHEMA_VERSION`; ``ts`` is ``time.time()`` at emit;
``seq`` is the per-emitter monotone index (the deterministic tie-break for
fleet-shard merging); ``worker`` is present only on fleet worker shards.

Event kinds are plain dataclasses registered in :data:`EVENT_TYPES`.
``to_record`` / ``from_record`` round-trip them losslessly, and
``validate_record`` is the schema check used by ``scripts/telemetry_report.py
--validate`` and the telemetry-smoke CI job.

Note: :class:`FaultEvent` here is the *telemetry record* of a fault firing or
being handled; ``repro.runtime.faults.FaultEvent`` is the *injection plan
entry*.  They are distinct types in distinct namespaces.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, IO, Iterable, List, Optional, Sequence, Type

SCHEMA_VERSION = 1

#: record keys added by the emitter envelope, not by the event dataclass
ENVELOPE_KEYS = ("v", "ts", "kind", "seq", "worker")


@dataclasses.dataclass
class RunEvent:
    """Run lifecycle marker; ``phase="start"`` carries the spec manifest."""
    KIND = "run"
    phase: str = "start"            # start | end
    engine: str = ""
    quantize: str = ""
    arch: str = ""
    spec: Optional[dict] = None     # CLI-field manifest (start only)
    steps: int = 0                  # completed steps (end only)
    final_loss: Optional[float] = None


@dataclasses.dataclass
class StepEvent:
    KIND = "step"
    step: int = 0
    loss: float = 0.0
    seconds: float = 0.0


@dataclasses.dataclass
class FaultEvent:
    """A fault firing (``source="injector"``) or being handled by the
    resilient loop (``source="loop"``)."""
    KIND = "fault"
    step: int = 0
    fault: str = ""                 # oom | crash | nan | stall | corrupt | exception
    injected: bool = False
    source: str = "loop"            # injector | loop
    error: str = ""


@dataclasses.dataclass
class DegradeEvent:
    """One rung of the memory-pressure ladder applied mid-run.

    ``seq_len`` (not ``seq``): the envelope reserves ``seq`` for the
    emitter's monotone record index."""
    KIND = "degrade"
    step: int = 0
    rung: str = ""
    trigger: str = "oom"            # oom | watermark
    engine: str = ""
    quantize: str = ""
    batch: int = 0
    seq_len: int = 0
    predicted_peak_mb: float = 0.0


@dataclasses.dataclass
class GuardEvent:
    """StepGuard rejection with the EWMA state that justified it."""
    KIND = "guard"
    step: int = 0
    reason: str = ""                # nonfinite_loss | nonfinite_norm | loss_spike | norm_spike
    detail: str = ""
    loss_ewma: Optional[float] = None
    norm_ewma: Optional[float] = None
    rejected: int = 0
    budget: int = 0


@dataclasses.dataclass
class AdmissionEvent:
    """Serve-loop request lifecycle: admit / reject / complete."""
    KIND = "admission"
    action: str = ""                # admit | reject | complete
    rid: str = ""
    adapter: str = ""
    reason: str = ""                # reject: pages | headroom | tiles | store
    step: int = 0


@dataclasses.dataclass
class CheckpointEvent:
    KIND = "checkpoint"
    action: str = ""                # save | restore | quarantine
    step: int = 0
    seconds: float = 0.0
    path: str = ""


@dataclasses.dataclass
class WatermarkEvent:
    """Memory watermark sample around a step boundary."""
    KIND = "watermark"
    step: int = 0
    measured_mb: float = 0.0
    peak_mb: float = 0.0
    predicted_mb: float = 0.0       # memsim predicted peak for the live spec
    ratio: float = 0.0              # peak_mb / predicted_mb (0 if unknown)
    source: str = ""                # device_stats | live_arrays


EVENT_TYPES: Dict[str, Type] = {
    cls.KIND: cls
    for cls in (RunEvent, StepEvent, FaultEvent, DegradeEvent, GuardEvent,
                AdmissionEvent, CheckpointEvent, WatermarkEvent)
}

# an event field named like an envelope key would silently clobber the
# envelope in to_record — refuse at import time
for _cls in EVENT_TYPES.values():
    _clash = {f.name for f in dataclasses.fields(_cls)} & set(ENVELOPE_KEYS)
    if _clash:
        raise TypeError(f"{_cls.__name__} field(s) {sorted(_clash)} collide "
                        f"with the record envelope {ENVELOPE_KEYS}")


def to_record(event, *, seq: int = 0, worker: Optional[int] = None,
              ts: Optional[float] = None) -> dict:
    """Wrap a typed event in the wire envelope."""
    rec = {"v": SCHEMA_VERSION,
           "ts": time.time() if ts is None else ts,
           "kind": event.KIND, "seq": seq}
    if worker is not None:
        rec["worker"] = worker
    rec.update(dataclasses.asdict(event))
    return rec


def from_record(rec: dict):
    """Typed event back out of a wire record (envelope keys dropped)."""
    cls = EVENT_TYPES[rec["kind"]]
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in rec.items() if k in fields})


def validate_record(rec: dict) -> List[str]:
    """Schema check for one wire record; returns a list of problems."""
    errs: List[str] = []
    if not isinstance(rec, dict):
        return [f"record is not an object: {type(rec).__name__}"]
    v = rec.get("v")
    if v != SCHEMA_VERSION:
        errs.append(f"schema version {v!r} != {SCHEMA_VERSION}")
    if not isinstance(rec.get("ts"), (int, float)):
        errs.append("missing/non-numeric 'ts'")
    if not isinstance(rec.get("seq"), int):
        errs.append("missing/non-int 'seq'")
    kind = rec.get("kind")
    cls = EVENT_TYPES.get(kind)
    if cls is None:
        errs.append(f"unknown kind {kind!r}")
        return errs
    for f in dataclasses.fields(cls):
        if f.name not in rec:
            errs.append(f"{kind}: missing field {f.name!r}")
    extra = set(rec) - {f.name for f in dataclasses.fields(cls)} \
        - set(ENVELOPE_KEYS)
    for k in sorted(extra):
        errs.append(f"{kind}: unexpected field {k!r}")
    return errs


# --------------------------------------------------------------------- sinks
class MemorySink:
    """Keeps records in a list; the default sink (snapshots, tests)."""

    def __init__(self):
        self.records: List[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-one-line-per-record file sink; flushes per emit so crashed or
    injected-fault runs still leave a complete timeline prefix."""

    def __init__(self, path: str):
        self.path = path
        self._fh: Optional[IO[str]] = None

    def emit(self, record: dict) -> None:
        if self._fh is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "a")
        self._fh.write(json.dumps(record, sort_keys=True) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


# ---------------------------------------------------------------- jsonl I/O
def read_jsonl(path: str) -> List[dict]:
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def merge_jsonl_shards(shards: Sequence[str], out_path: str) -> List[dict]:
    """Merge per-worker JSONL shards into one deterministic fleet timeline.

    Sort key is ``(ts, worker, seq)`` — identical regardless of shard file
    order or interleaving, so the merged file is byte-stable (asserted by
    tests/test_telemetry.py).  Returns the merged records.
    """
    records: List[dict] = []
    for path in shards:
        records.extend(read_jsonl(path))
    records.sort(key=lambda r: (r.get("ts", 0.0), str(r.get("worker", "")),
                                r.get("seq", 0)))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as fh:
        for rec in records:
            fh.write(json.dumps(rec, sort_keys=True) + "\n")
    return records
