"""Nested wall-clock trace spans with Chrome-trace/Perfetto export.

A :class:`Tracer` records complete ("ph": "X") spans; nesting comes from the
enter/exit timing, which Perfetto and chrome://tracing reconstruct into a
flame view.  Disabled tracing costs nothing: :data:`NULL_SPAN` is one shared
``contextlib``-style no-op context manager, so ``tracer.span(...)`` on a
disabled tracer allocates no objects (asserted by tests).

``jax.profiler`` start/stop hooks live here too (behind ``--profile``); they
are best-effort and never fail the run.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional


class _NullSpan:
    """Shared no-op context manager (singleton: :data:`NULL_SPAN`)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One live span; records itself on the tracer at ``__exit__``."""

    __slots__ = ("tracer", "name", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str):
        self.tracer = tracer
        self.name = name
        self.t0 = 0.0
        self.depth = 0

    def __enter__(self):
        tr = self.tracer
        self.depth = len(tr._stack)
        tr._stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        tr = self.tracer
        if tr._stack and tr._stack[-1] is self:
            tr._stack.pop()
        tr.finished.append((self.name, self.t0 - tr.epoch, dur, self.depth))
        return False


class Tracer:
    """Collects finished spans as ``(name, start_s, dur_s, depth)`` tuples
    relative to the tracer's epoch."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.epoch = time.perf_counter()
        self.finished: List[tuple] = []
        self._stack: List[Span] = []

    def span(self, name: str):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name)

    # ------------------------------------------------------------ export
    def chrome_trace(self) -> List[dict]:
        """Chrome trace event format: complete events, µs timestamps."""
        pid = os.getpid()
        tid = threading.get_ident() % 10_000
        return [{"name": name, "ph": "X", "ts": round(start * 1e6, 1),
                 "dur": round(dur * 1e6, 1), "pid": pid, "tid": tid,
                 "args": {"depth": depth}}
                for name, start, dur, depth in self.finished]

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump({"traceEvents": self.chrome_trace(),
                       "displayTimeUnit": "ms"}, fh)
        return path

    def totals(self) -> dict:
        """Per-name aggregate {count, total_s} — cheap summary for reports."""
        agg: dict = {}
        for name, _start, dur, _depth in self.finished:
            row = agg.setdefault(name, {"count": 0, "total_s": 0.0})
            row["count"] += 1
            row["total_s"] += dur
        return agg


NULL_TRACER = Tracer(enabled=False)


# ----------------------------------------------------------- jax.profiler
def start_profiler(log_dir: str) -> bool:
    """Best-effort ``jax.profiler.start_trace``; returns success."""
    try:
        import jax
        os.makedirs(log_dir, exist_ok=True)
        jax.profiler.start_trace(log_dir)
        return True
    except Exception:  # pragma: no cover - platform dependent
        return False


def stop_profiler() -> bool:
    try:
        import jax
        jax.profiler.stop_trace()
        return True
    except Exception:  # pragma: no cover - platform dependent
        return False
