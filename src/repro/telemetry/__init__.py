"""Telemetry runtime: structured metrics, typed events, trace spans.

:class:`Telemetry` is the one object threaded through the trainer, resilient
loop, guard, and serve loop.  Disabled (the default) it is a frozen shell:
``enabled`` is False, ``span()`` returns the shared no-op singleton, and
``emit()`` returns immediately — the hot step path pays one attribute check
and nothing else (asserted by tests/test_telemetry.py).  Enabled, it owns

* a :class:`~repro.telemetry.metrics.MetricRegistry` (counters / gauges /
  histograms, unified across train/serve/autotune),
* event sinks (in-memory always; JSONL under ``--telemetry-dir``),
* a :class:`~repro.telemetry.spans.Tracer` with Chrome-trace export, and
* optional ``jax.profiler`` capture (``--profile on``).

The module also hosts the structured *console* logging choke point
(:func:`log_step`, :func:`log_run_summary`) that replaced the ad-hoc
``_log_step`` print path in ``api/trainer.py`` and the fault-counter prints
in ``launch/train.py`` — both respect ``--quiet``.
"""
from __future__ import annotations

import logging
import os
from typing import List, Optional

from repro.telemetry import events as ev
from repro.telemetry import spans as sp
from repro.telemetry.events import (AdmissionEvent, CheckpointEvent,
                                    DegradeEvent, FaultEvent, GuardEvent,
                                    RunEvent, SCHEMA_VERSION, StepEvent,
                                    WatermarkEvent)
from repro.telemetry.memwatch import MemoryWatermark
from repro.telemetry.metrics import (Counter, CounterGroup, Gauge, Histogram,
                                     MetricRegistry)
from repro.telemetry.spans import NULL_SPAN, Tracer

__all__ = [
    "Telemetry", "DISABLED", "MemoryWatermark", "MetricRegistry",
    "CounterGroup", "Counter", "Gauge", "Histogram", "Tracer", "NULL_SPAN",
    "SCHEMA_VERSION", "RunEvent", "StepEvent", "FaultEvent", "DegradeEvent",
    "GuardEvent", "AdmissionEvent", "CheckpointEvent", "WatermarkEvent",
    "log_step", "log_run_summary",
]

log = logging.getLogger("repro.train")


class Telemetry:
    """Event emitter + metric registry + tracer for one run."""

    def __init__(self, enabled: bool = True, out_dir: Optional[str] = None,
                 worker: Optional[int] = None, profile: bool = False,
                 sinks: Optional[list] = None):
        self.enabled = enabled
        self.out_dir = out_dir
        self.worker = worker
        self.registry = MetricRegistry()
        self.tracer = Tracer(enabled=enabled)
        self._seq = 0
        self.sinks: list = []
        self._profiling = False
        if not enabled:
            return
        self.memory_sink = ev.MemorySink()
        self.sinks = list(sinks) if sinks is not None else [self.memory_sink]
        if sinks is not None and not any(
                isinstance(s, ev.MemorySink) for s in self.sinks):
            self.memory_sink = None  # caller opted out of in-memory capture
        if out_dir:
            name = ("events.jsonl" if worker is None
                    else f"worker_{worker}.jsonl")
            self.sinks.append(ev.JsonlSink(os.path.join(out_dir, name)))
        if profile and out_dir:
            self._profiling = sp.start_profiler(
                os.path.join(out_dir, "profile"))
        # autotune counters are module-global (kernels cannot depend on a
        # run-scoped object); adopt them so snapshots include cache traffic
        try:
            from repro.kernels import autotune
            self.registry.register_group(autotune.COUNTERS)
        except Exception:  # pragma: no cover - kernels optional in tests
            pass

    @classmethod
    def from_spec(cls, spec, worker: Optional[int] = None) -> "Telemetry":
        """Build from TrainSpec telemetry fields (PR 3 CLI contract)."""
        enabled = getattr(spec, "telemetry", "off") == "on"
        if not enabled:
            return DISABLED
        out_dir = getattr(spec, "telemetry_dir", "") or os.path.join(
            spec.ckpt_dir, "telemetry")
        return cls(enabled=True, out_dir=out_dir, worker=worker,
                   profile=getattr(spec, "profile", "off") == "on")

    # ------------------------------------------------------------ emission
    def emit(self, event) -> None:
        if not self.enabled:
            return
        rec = ev.to_record(event, seq=self._seq, worker=self.worker)
        self._seq += 1
        for s in self.sinks:
            s.emit(rec)

    def span(self, name: str):
        if not self.enabled:
            return NULL_SPAN
        return self.tracer.span(name)

    # ------------------------------------------------------------- queries
    def events(self, kind: Optional[str] = None) -> List[dict]:
        """In-memory records (empty when disabled or memory sink opted out)."""
        sink = getattr(self, "memory_sink", None)
        if sink is None:
            return []
        if kind is None:
            return list(sink.records)
        return [r for r in sink.records if r.get("kind") == kind]

    def counts_by_kind(self) -> dict:
        out: dict = {}
        for r in self.events():
            out[r["kind"]] = out.get(r["kind"], 0) + 1
        return out

    # ------------------------------------------------------------ shutdown
    def close(self) -> None:
        if not self.enabled:
            return
        if self._profiling:
            sp.stop_profiler()
            self._profiling = False
        if self.out_dir and self.tracer.finished:
            self.tracer.save(os.path.join(self.out_dir, "trace.json"))
        for s in self.sinks:
            s.close()


#: module-level disabled singleton — safe default for every integration point
DISABLED = Telemetry(enabled=False)


# ----------------------------------------------------- console choke point
def log_step(res, interval: int, quiet: bool = False) -> None:
    """The single console step-log path (was ``_log_step`` in trainer)."""
    if quiet:
        return
    if interval > 0 and res.step % interval == 0:
        log.info("step %5d loss %.4f %.3fs/step",
                 res.step, float(res.loss), res.seconds)


def log_run_summary(result, quiet: bool = False) -> None:
    """End-of-run console summary (was ad-hoc prints in launch/train.py)."""
    if quiet:
        return
    hist = getattr(result, "history", None)
    if hist:
        log.info("done: final loss %.4f over %d steps",
                 float(hist[-1].loss), len(hist))
    counters = getattr(result, "fault_counts", None) or {}
    nonzero = {k: v for k, v in counters.items() if v}
    if nonzero:
        log.info("faults survived: %s", nonzero)
    degr = getattr(result, "degradations", None)
    if degr:
        log.info("degraded %d time(s): %s", len(degr), " -> ".join(degr))
    metrics = getattr(result, "metrics", None) or {}
    wm = metrics.get("watermark")
    if wm and wm.get("measured_peak_mb"):
        log.info("memory watermark: measured %.1f MB vs predicted %.1f MB "
                 "(ratio %.2f, source=%s)", wm["measured_peak_mb"],
                 wm["predicted_peak_mb"], wm["ratio"], wm["source"])
