"""Parameter & activation PartitionSpecs for the (pod, data, model) mesh.

Megatron-style tensor parallelism on the ``model`` axis:
  * column-parallel: q/k/v projections, MLP gate/up, embedding head
  * row-parallel:    o projection, MLP down
  * expert-parallel: MoE expert stacks sharded on their leading E dim
  * LoRA factors: the factor dim touching a sharded weight dim is sharded the
    same way; the rank dim (r ≤ 32) is always replicated.
  * vocab-parallel embedding + logits.

Activations: batch on ``(pod, data)``; between blocks the scan carry is
additionally sequence-sharded on ``model`` (Megatron sequence parallelism) —
without this, per-block input checkpoints of the largest archs exceed HBM
(DESIGN.md §4, EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# projections whose weight is column-parallel ([d_in, d_out·shard]) keyed by
# their parent dict name; row-parallel analogously.
_COL = {"q", "k", "v", "gate", "up", "x_proj", "gate_proj", "rg_w", "in_w",
        "g", "w"}
_ROW = {"o", "down", "out_proj"}
# rwkv channel-mix reuses k/v/r names with different roles
_CM_COL = {"k", "r"}
_CM_ROW = {"v"}


def _keys(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "idx"):
            out.append(p.idx)
    return out


def _trailing_spec(keys, leaf) -> Tuple:
    last = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else None
    in_moe = "moe" in keys
    in_cm = "cm" in keys

    if last == "tok":
        return ("model", None)          # vocab-parallel embedding
    if last == "head":
        return (None, "model")          # vocab-parallel logits
    if last == "router":
        return (None, None)

    # quantized frozen weight: ``w`` became a {"q","scale"} (int8) or
    # {"q4","scale"[,"code","kpad"]} (packed 4-bit) dict, so the path ends
    # [..., proj, "w", <fmt key>]. q/q4 keep w's layout (q4's halved K dim
    # is dropped by _guard when the axis stops dividing it); scale is
    # [..., 1, d_out] and _guard drops any axis landing on the size-1 dim.
    if last in ("q", "q4", "scale") and parent == "w":
        return _trailing_spec(keys[:-1], leaf)
    # nf4 codebook / odd-K parity marker: trailing 16/1 dim is replicated
    # (never shard a codebook), leading batch dims padded with None anyway
    if last in ("code", "kpad") and parent == "w":
        return (None,)

    if in_moe and last in ("w", "a", "b") and parent in ("gate", "up", "down") \
            and hasattr(leaf, "ndim"):
        return ("model", None, None)    # expert-parallel stacks [E, ·, ·]

    col = (parent in _CM_COL) if in_cm else (parent in _COL)
    row = (parent in _CM_ROW) if in_cm else (parent in _ROW)

    if last == "w" and (col or row):
        return (None, "model") if col else ("model", None)
    if last == "a":                     # LoRA A: [d_in, r]
        return ("model", None) if row else (None, None)
    if last == "b":                     # LoRA B: [r, d_out]
        return (None, "model") if col else (None, None)
    if last == "bias":
        return ("model",) if col else (None,)
    # norms, token-shift mixes, decay vectors, conv weights, …: replicated
    return tuple([None] * getattr(leaf, "ndim", 1))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _guard(spec_dims, leaf, mesh: Optional[Mesh]):
    """Drop axes whose size does not divide the corresponding dim (pjit
    in_shardings require exact divisibility)."""
    if mesh is None:
        return spec_dims
    shape = getattr(leaf, "shape", ())
    out = []
    for i, ax in enumerate(spec_dims):
        if ax is not None and i < len(shape) and \
                shape[i] % _axis_size(mesh, ax) != 0:
            out.append(None)
        else:
            out.append(ax)
    return tuple(out)


def param_specs(cfg: ArchConfig, params, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params`` (handles stacked leading dims).

    Pass ``mesh`` to drop shardings whose axis size doesn't divide the dim
    (e.g. whisper's vocab 51865 on a 16-way model axis)."""
    def one(path, leaf):
        keys = _keys(path)
        t = _trailing_spec(keys, leaf)
        extra = leaf.ndim - len(t)
        if extra < 0:  # vector param matched a matrix rule (defensive)
            return P(*([None] * leaf.ndim))
        return P(*_guard(tuple([None] * extra + list(t)), leaf, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


def opt_specs(cfg: ArchConfig, opt_state, mesh: Optional[Mesh] = None) -> Any:
    """Optimizer state: scalars replicated; moment trees mirror param specs."""
    def one(path, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return P()
        keys = _keys(path)
        t = _trailing_spec([k for k in keys if k not in ("m", "v")] or keys,
                           leaf)
        extra = leaf.ndim - len(t)
        if extra < 0:
            return P(*([None] * leaf.ndim))
        return P(*_guard(tuple([None] * extra + list(t)), leaf, mesh))

    return jax.tree_util.tree_map_with_path(one, opt_state)


def dp_axes(mesh: Mesh) -> Tuple:
    """The composed data-parallel axes of a mesh: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Shard batch over DP axes when divisible, else replicate (long-context
    batch-1 decode shards the cache sequence dim instead)."""
    dp = dp_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if global_batch % size == 0 and global_batch >= size:
        return P(dp)
    return P()


def cache_specs(cfg: ArchConfig, cache, mesh: Mesh, global_batch: int) -> Any:
    """Decode-state sharding.

    * batch on the DP axes when divisible;
    * KV heads on ``model`` when divisible, else the cache **sequence** dim
      takes ``model`` (sequence-parallel KV cache — the common case for GQA
      archs with few KV heads on a 16-way model axis);
    * batch-1 long-context decode puts the sequence dim on DP too.
    """
    dp = dp_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    batch_on_dp = global_batch % size == 0 and global_batch >= size
    bspec = dp if batch_on_dp else None
    heads_divisible = cfg.n_kv_heads % mesh.shape["model"] == 0
    s_axes = []
    if not batch_on_dp:
        s_axes.extend(dp)
    if not heads_divisible:
        s_axes.append("model")
    sspec = tuple(s_axes) if s_axes else None
    hspec = "model" if heads_divisible else None

    def one(path, leaf):
        keys = _keys(path)
        last = keys[-1]
        nd = getattr(leaf, "ndim", 0)
        if last in ("k", "v") and nd >= 4:
            # [..., B, Hkv, S, D]
            t = (bspec, hspec, sspec, None)
        elif last == "wkv" and nd >= 4:
            t = (bspec, "model", None, None)      # [B, H, D, D]
        elif last in ("shift_tm", "shift_cm", "lru") and nd >= 2:
            t = (bspec, "model")
        elif last == "conv" and nd >= 3:
            t = (bspec, None, "model")
        elif last == "enc_out" and nd >= 3:
            t = (bspec, None, None)
        elif last == "len":
            return P()
        else:
            return P(*([None] * nd))
        extra = nd - len(t)
        return P(*_guard(tuple([None] * extra + list(t)), leaf, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


def activation_spec(mesh: Mesh, global_batch: int, *,
                    seq_on_model: bool = True) -> P:
    """Block-boundary activation sharding [B, N, d]: batch on DP axes and —
    Megatron SP — sequence on model."""
    b = batch_spec(mesh, global_batch)
    bdim = b if len(b) else None
    return P(bdim[0] if bdim else None, "model" if seq_on_model else None, None)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
