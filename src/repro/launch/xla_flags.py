"""XLA_FLAGS plumbing that must run *before* JAX initializes.

The host-platform device-count flag (``--xla_force_host_platform_device_count``)
is the whole basis of the emulated-fleet harness: one CPU process presents N
XLA devices, so the sharding stack (``launch/sharding.py``, ``runtime/
elastic.py``) runs real multi-device programs in CI. XLA reads the flag once,
when the backend initializes — setting it later silently does nothing, and
*overwriting* ``XLA_FLAGS`` (what ``launch/dryrun.py`` used to do) clobbers
whatever flags the user had exported.

This module therefore never imports ``jax`` at module level, appends instead
of overwriting, and warns loudly when it detects that the backend already
exists (the request cannot take effect in this process).
"""
from __future__ import annotations

import os
import re
import sys
import warnings

_COUNT_FLAG = "--xla_force_host_platform_device_count"


def jax_initialized() -> bool:
    """True when a JAX backend already exists in this process (at which
    point XLA_FLAGS edits are too late). Never initializes one itself."""
    if "jax" not in sys.modules:
        return False
    xb = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(xb, "_backends", None))


def force_host_device_count(n: int, *, env: dict = os.environ) -> bool:
    """Request ``n`` emulated host (CPU) devices by appending the XLA flag.

    Preserves every other flag already in ``XLA_FLAGS`` (an existing
    device-count request is replaced, not duplicated). Returns True when the
    request can still take effect; returns False — after a ``UserWarning`` —
    when JAX has already initialized a backend, in which case the caller
    should run the multi-device work in a fresh subprocess instead (see
    ``launch/fleet.py``).
    """
    flags = env.get("XLA_FLAGS", "")
    flags = re.sub(rf"{_COUNT_FLAG}=\d+\s*", "", flags).strip()
    env["XLA_FLAGS"] = (flags + f" {_COUNT_FLAG}={n}").strip()
    # editing a *copy* of the environment (for a subprocess) is always fine,
    # however far along this process's JAX is
    if env is not os.environ:
        return True
    if jax_initialized():
        warnings.warn(
            f"{_COUNT_FLAG}={n} was requested after JAX initialized its "
            "backend; the emulated device count cannot apply to this "
            "process. Launch a subprocess with the flag in its environment "
            "(launch/fleet.py does this) instead.", UserWarning,
            stacklevel=2)
        return False
    return True
