"""ShapeDtypeStruct stand-ins for every (arch × shape) cell.

``input_specs`` returns weak-type-correct, shardable specs with NO device
allocation — the dry-run lowers and compiles against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import sharding as sh
from repro.models import model as model_lib

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """(batch ShapeDtypeStructs, batch NamedShardings) for a training step."""
    B, N = shape.global_batch, shape.seq_len
    bs = sh.batch_spec(mesh, B)
    bdim = bs[0] if len(bs) else None
    specs = {
        "tokens": SDS((B, N), jnp.int32),
        "labels": SDS((B, N), jnp.int32),
    }
    shards = {
        "tokens": NamedSharding(mesh, P(bdim, None)),
        "labels": NamedSharding(mesh, P(bdim, None)),
    }
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        specs["frontend_embeds"] = SDS((B, cfg.frontend_tokens, cfg.d_model), dtype)
        shards["frontend_embeds"] = NamedSharding(mesh, P(bdim, None, None))
    if cfg.family == "audio":
        specs["enc_frames"] = SDS((B, cfg.encdec.encoder_seq, cfg.d_model), dtype)
        shards["enc_frames"] = NamedSharding(mesh, P(bdim, None, None))
    return specs, shards


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """(cache specs, cache shardings, token spec, token sharding).

    ``decode_*``/``long_*`` shapes: one new token against a cache holding
    ``seq_len`` previous positions.
    """
    B, S = shape.global_batch, shape.seq_len
    # pad the cache a divisibility-friendly amount past seq_len: the cache
    # holds seq_len valid positions plus the newly decoded token
    max_len = S + 256
    cache = jax.eval_shape(lambda: model_lib.init_cache(cfg, B, max_len))
    cache_specs = sh.cache_specs(cfg, cache, mesh, B)
    cache_shards = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cache_specs,
        is_leaf=lambda x: isinstance(x, P))
    bs = sh.batch_spec(mesh, B)
    bdim = bs[0] if len(bs) else None
    tok = SDS((B, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, P(bdim, None))
    return cache, cache_shards, tok, tok_shard


def param_struct(cfg: ArchConfig):
    """Abstract params (no allocation) via eval_shape on the initializer."""
    return jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg))
