from repro.launch import inputs, mesh, sharding  # noqa: F401
