"""Emulated-fleet launcher: real multi-device programs on one CPU host.

``--xla_force_host_platform_device_count=N`` makes a single CPU process
present N XLA devices, so the whole sharding stack — ``launch/sharding.py``
PartitionSpecs, the Trainer's sharded jit, ``runtime/elastic.py`` resizes,
HLO collectives — runs for real in CI, no accelerators required. XLA reads
the flag exactly once, when the backend initializes, so every fleet runs in
a **fresh subprocess** with the flag placed in its environment
(``xla_flags.force_host_device_count`` on an env *copy*); whatever JAX state
the parent process has is irrelevant.

Protocol: the parent writes a JSON payload (task + spec overrides), the
worker (``python -m repro.launch.fleet payload.json result.json``) runs it
and writes a JSON result; arrays travel via ``.npz`` side files (payload
``"out"``). Tasks:

* ``train`` — deterministic synthetic-batch training through the Trainer
  facade; returns losses + per-step wall times, dumps final state.
* ``collectives`` — compile the sharded step, parse collective payload
  bytes from the HLO (``roofline.analysis.collective_bytes``) and compare
  with the analytic prediction (``predicted_grad_sync_bytes``).
* ``elastic`` — live 8→4→8 resize through ``Trainer.resize`` vs the
  checkpoint-restore path vs an uninterrupted run, all inside the worker.

Used by tests/multihost/ (correctness) and benchmarks/scaling.py (the
step-time-vs-device-count curve).
"""
from __future__ import annotations

import glob
import json
import os
import subprocess
import sys
import tempfile
import traceback
from typing import Dict, List, Optional

from repro.launch.xla_flags import force_host_device_count

#: steps discarded from the front of every timing series (compile + warm-up)
WARMUP_STEPS = 1


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------


def fleet_env(devices: int, env: Optional[dict] = None) -> dict:
    """A subprocess environment presenting ``devices`` emulated CPU devices.
    Starts from (a copy of) the current environment: user XLA_FLAGS survive,
    only the device-count flag is replaced."""
    env = dict(os.environ if env is None else env)
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("REPRO_PALLAS_INTERPRET", "1")
    force_host_device_count(devices, env=env)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def run_fleet(payload: dict, *, devices: int, timeout: float = 1500.0) -> dict:
    """Run one worker task on an emulated ``devices``-device fleet and return
    its result dict. Raises RuntimeError (with the worker's stderr tail) on
    a non-zero exit or a worker-reported error."""
    with tempfile.TemporaryDirectory(prefix="repro_fleet_") as td:
        ppath = os.path.join(td, "payload.json")
        rpath = os.path.join(td, "result.json")
        with open(ppath, "w") as f:
            json.dump(payload, f)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.fleet", ppath, rpath],
            env=fleet_env(devices), capture_output=True, text=True,
            timeout=timeout)
        if proc.returncode != 0 or not os.path.exists(rpath):
            raise RuntimeError(
                f"fleet worker ({devices} devices) failed rc="
                f"{proc.returncode}:\n{proc.stderr[-4000:]}")
        with open(rpath) as f:
            result = json.load(f)
    if result.get("status") != "ok":
        raise RuntimeError(
            f"fleet worker ({devices} devices) errored:\n"
            f"{result.get('error')}\n{result.get('traceback', '')[-4000:]}")
    return result


def merge_fleet_telemetry(telemetry_dir: str,
                          out_name: str = "fleet.jsonl") -> Optional[str]:
    """Merge per-worker ``worker_<id>.jsonl`` shards under ``telemetry_dir``
    into one deterministic timeline (sorted by ``(ts, worker, seq)`` — see
    ``repro.telemetry.events.merge_jsonl_shards``). Returns the merged path,
    or None when no shards exist. Byte-deterministic in the shard *set*, not
    the glob order, so re-merges and shuffled worker finishes agree."""
    from repro.telemetry.events import merge_jsonl_shards

    shards: List[str] = sorted(
        glob.glob(os.path.join(telemetry_dir, "worker_*.jsonl")))
    if not shards:
        return None
    out = os.path.join(telemetry_dir, out_name)
    merge_jsonl_shards(shards, out)
    return out


# ---------------------------------------------------------------------------
# worker side (fresh subprocess — jax imported lazily, after XLA_FLAGS took
# effect at backend init)
# ---------------------------------------------------------------------------


def synth_batch(vocab: int, batch: int, seq: int, seed: int, step: int) -> dict:
    """Deterministic synthetic batch — a pure function of (seed, step) and
    the *global* shape, so every device count sees identical data."""
    import numpy as np

    rng = np.random.default_rng((seed, step))
    toks = rng.integers(0, vocab, (batch, seq), dtype=np.int32)
    return {"tokens": toks, "labels": toks}


def _flat(tree, prefix: str) -> Dict[str, "object"]:
    """Flatten a pytree to {path-string: ndarray} for npz interchange.
    (None leaves — frozen slots — are not pytree leaves and drop out
    identically on every worker, so flat keys always line up.)"""
    import jax
    import numpy as np

    out = {}
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in leaves:
        key = prefix + "/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _mesh_axes(mesh) -> Dict[str, int]:
    if mesh is None:
        return {}
    return {a: int(mesh.shape[a]) for a in mesh.axis_names}


def _make_trainer(payload: dict):
    from repro.api.spec import TrainSpec
    from repro.api.trainer import Trainer

    spec = TrainSpec(**payload.get("spec", {}))
    return Trainer.from_spec(spec)


def _batch_struct(tr):
    import jax
    import numpy as np

    live = tr.live_spec
    s = jax.ShapeDtypeStruct((live.batch, live.seq), np.int32)
    return {"tokens": s, "labels": s}


def _worker_telemetry(payload: dict):
    """Per-worker Telemetry writing a ``worker_<id>.jsonl`` shard when the
    payload carries ``telemetry_dir`` (parent merges shards afterwards with
    :func:`merge_fleet_telemetry`); the DISABLED singleton otherwise."""
    from repro import telemetry as tele

    tdir = payload.get("telemetry_dir")
    if not tdir:
        return tele.DISABLED
    os.makedirs(tdir, exist_ok=True)
    return tele.Telemetry(enabled=True, out_dir=tdir,
                          worker=int(payload.get("worker_id", 0)))


def task_train(payload: dict) -> dict:
    import time

    import jax
    import numpy as np

    from repro import telemetry as tele

    tr = _make_trainer(payload)
    params, opt_state = tr.init_state()
    params, opt_state = tr.shard_state(params, opt_state)
    spec = tr.live_spec
    tel = _worker_telemetry(payload)
    memwatch = tele.MemoryWatermark() if tel.enabled else None
    tel.emit(tele.RunEvent(phase="start", engine=spec.engine,
                           quantize=spec.quantize, arch=spec.arch,
                           steps=int(payload.get("steps", spec.steps))))
    losses, times = [], []
    try:
        for step in range(int(payload.get("steps", spec.steps))):
            batch = synth_batch(tr.cfg.vocab, spec.batch, spec.seq,
                                spec.seed, step)
            t0 = time.perf_counter()
            with tel.span("step"):
                params, opt_state, loss = jax.block_until_ready(
                    tr.step_fn(params, opt_state, batch))
            dt = time.perf_counter() - t0
            times.append(dt)
            losses.append(float(loss))
            tel.emit(tele.StepEvent(step=step, loss=float(loss), seconds=dt))
            if memwatch is not None:
                m = memwatch.sample()
                tel.emit(tele.WatermarkEvent(
                    step=step, measured_mb=m["measured_mb"],
                    peak_mb=m["peak_mb"], source=m["source"]))
        tel.emit(tele.RunEvent(
            phase="end", steps=len(losses),
            final_loss=losses[-1] if losses else 0.0))
    finally:
        tel.close()
    if payload.get("out"):
        np.savez(payload["out"], **_flat(params, "params"),
                 **_flat(opt_state, "opt"))
    steady = times[WARMUP_STEPS:] or times
    result = {"losses": losses, "step_times_s": times,
              "step_time_s": float(np.median(steady)),
              "devices": jax.device_count(), "mesh": _mesh_axes(tr.mesh)}
    if tel.enabled and tel.out_dir:
        result["telemetry_shard"] = os.path.join(
            tel.out_dir, f"worker_{tel.worker}.jsonl")
    return result


def task_collectives(payload: dict) -> dict:
    import contextlib

    import jax

    from repro.models.model import split_params
    from repro.roofline.analysis import (collective_bytes,
                                         predicted_grad_sync_bytes)

    tr = _make_trainer(payload)
    pstruct, ostruct = tr._state_struct(tr.live_spec)
    ctx = tr.mesh if tr.mesh is not None else contextlib.nullcontext()
    with ctx:
        txt = tr._jit_step.lower(pstruct, ostruct,
                                 _batch_struct(tr)).compile().as_text()
    coll = collective_bytes(txt)
    train, _ = split_params(pstruct)
    leaves = jax.tree_util.tree_leaves(train)
    n_trainable = sum(l.size for l in leaves)
    # Two subtleties in the analytic floor vs what HLO parsing can see:
    # (1) grads sync in the model's *compute* dtype (``cfg.dtype``) — params
    #     may be stored wider (f32 masters), but the all-reduce payload XLA
    #     emits is the gradient;
    # (2) the structured backward walks the L stacked blocks in a loop, so
    #     the compiled program contains ONE loop body whose all-reduces
    #     cover a single layer slice of the blocks' grads (executed L times
    #     at run time). Static HLO byte-parsing counts that body once, so
    #     the floor on *static* bytes is the per-layer slice of stacked
    #     leaves plus any non-stacked trainables in full.
    import jax.numpy as jnp
    item = jnp.dtype(tr.cfg.dtype).itemsize
    blk_ids = {id(l) for l in jax.tree_util.tree_leaves(
        train.get("blocks", {}) if isinstance(train, dict) else {})}
    static_elems = sum(l.size // l.shape[0] if id(l) in blk_ids else l.size
                       for l in leaves)
    trainable_bytes = n_trainable * item
    static_trainable_bytes = static_elems * item
    axes = _mesh_axes(tr.mesh)
    return {"collective_bytes": coll, "n_trainable": int(n_trainable),
            "trainable_bytes": int(trainable_bytes),
            "static_trainable_bytes": int(static_trainable_bytes),
            "predicted_grad_sync_bytes":
                predicted_grad_sync_bytes(static_trainable_bytes, axes,
                                          dtype_bytes=1),
            "devices": jax.device_count(), "mesh": axes}


def task_elastic(payload: dict) -> dict:
    """8→4→8 elastic resize, three ways, all inside this worker:

    * A — uninterrupted run on the full fleet (reference trajectory);
    * B — live resize through ``Trainer.resize`` at the phase boundaries;
    * C — checkpoint path: state round-trips through host numpy copies and
      fresh Trainer instances per mesh (what a real restore does).

    B and C execute the *same program sequence*, so they must be
    bit-identical — that is the elasticity contract. A runs a different
    XLA SPMD partitioning per device count, so A-vs-B agrees only to
    float tolerance (see docs/sharding.md)."""
    import jax
    import numpy as np

    from repro.api.trainer import Trainer
    from repro.api.spec import TrainSpec
    from repro.runtime.elastic import make_mesh_from_devices, reshard_tree
    from repro.launch import sharding as sh

    spec = TrainSpec(**payload.get("spec", {}))
    phases = payload.get("phases", [2, 2, 2])   # steps per mesh phase
    n_full = jax.device_count()
    n_small = int(payload.get("shrink_to", max(n_full // 2,
                                               spec.model_parallel)))
    mp = spec.model_parallel
    dev_full, dev_small = jax.devices(), jax.devices()[:n_small]

    def batches():
        step = 0
        while True:
            yield synth_batch(TrainerRef.cfg.vocab, spec.batch, spec.seq,
                              spec.seed, step)
            step += 1

    # --- A: uninterrupted on the full fleet
    TrainerRef = Trainer.from_spec(spec)
    params_a, opt_a = TrainerRef.shard_state(*TrainerRef.init_state())
    gen = batches()
    losses_a = []
    for _ in range(sum(phases)):
        params_a, opt_a, loss = TrainerRef.step_fn(params_a, opt_a, next(gen))
        losses_a.append(float(loss))

    # --- reshard_tree round trip is placement-only (bit-exact)
    mesh_small = make_mesh_from_devices(dev_small, mp)
    moved = reshard_tree(params_a, mesh_small,
                         sh.param_specs(TrainerRef.cfg, params_a, mesh_small))
    back = reshard_tree(moved, TrainerRef.mesh,
                        sh.param_specs(TrainerRef.cfg, params_a,
                                       TrainerRef.mesh))
    reshard_bitexact = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(params_a),
                        jax.tree_util.tree_leaves(back)))

    # --- B: live resize through the Trainer facade
    tr = Trainer.from_spec(spec)
    params_b, opt_b = tr.shard_state(*tr.init_state())
    gen = batches()
    losses_b = []
    plan = [(dev_full, phases[0]), (dev_small, phases[1]),
            (dev_full, phases[2])]
    for i, (devs, n) in enumerate(plan):
        if i > 0:
            params_b, opt_b = tr.resize(devs, params=params_b,
                                        opt_state=opt_b)
        for _ in range(n):
            params_b, opt_b, loss = tr.step_fn(params_b, opt_b, next(gen))
            losses_b.append(float(loss))

    # --- C: checkpoint-restore path (host round trip + fresh Trainer)
    def to_host(tree):
        return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

    gen = batches()
    losses_c = []
    state = None
    for i, (devs, n) in enumerate(plan):
        mesh = make_mesh_from_devices(list(devs), mp)
        trc = Trainer.from_spec(spec, mesh=mesh)
        if state is None:
            state = trc.init_state()
        params_c, opt_c = trc.shard_state(*state)
        for _ in range(n):
            params_c, opt_c, loss = trc.step_fn(params_c, opt_c, next(gen))
            losses_c.append(float(loss))
        state = (to_host(params_c), to_host(opt_c))

    leaves = lambda t: [np.asarray(x) for x in jax.tree_util.tree_leaves(t)]
    b_vs_c_bitwise = (
        losses_b == losses_c and
        all(np.array_equal(x, y) for x, y in zip(leaves(params_b),
                                                 leaves(state[0]))) and
        all(np.array_equal(x, y) for x, y in zip(leaves(opt_b),
                                                 leaves(state[1]))))
    b_vs_a_maxdiff = max(
        float(np.max(np.abs(x - y)))
        for x, y in zip(leaves(params_a), leaves(params_b)))
    return {"reshard_bitexact": bool(reshard_bitexact),
            "b_vs_c_bitwise": bool(b_vs_c_bitwise),
            "b_vs_a_maxdiff": b_vs_a_maxdiff,
            "losses_a": losses_a, "losses_b": losses_b,
            "losses_c": losses_c,
            "devices": n_full, "shrink_to": n_small}


def task_ladder(payload: dict) -> dict:
    """Sharding × resilience seam: every degradation-ladder rung reachable
    from the payload spec must *build, compile and run* a sharded step on
    the live model-parallel mesh — halved batches falling below the DP size
    (batch_spec replicates), int8's ``{"q","scale"}`` leaves (param_specs
    reuses the w layout), truncated seqs breaking Megatron-SP divisibility
    (act_spec recomputed per switch) all included."""
    import jax
    import numpy as np

    from repro.core.quant import quantize_params
    from repro.runtime import degrade as degrade_mod

    tr = _make_trainer(payload)
    base = tr.live_spec
    params0, opt0 = tr.shard_state(*tr.init_state())
    rungs = []
    for cand, rung in degrade_mod.DegradationLadder().candidates(base):
        try:
            tr._switch_to(cand)
        except Exception as e:   # unbuildable rung (Trainer skips these too)
            rungs.append({"rung": rung, "built": False,
                          "reason": f"{type(e).__name__}: {e}"})
            continue
        params, opt_state = params0, opt0
        if cand.quantize != base.quantize:
            new_params = quantize_params(params, cand.quantize)
            opt_state = degrade_mod.carry_opt_state(opt_state, params,
                                                    new_params)
            params = tr.shard_state(new_params)
        live = tr.live_spec
        batch = synth_batch(tr.cfg.vocab, live.batch, live.seq,
                            live.seed, 0)
        _, _, loss = tr.step_fn(params, opt_state, batch)
        rungs.append({"rung": rung, "built": True,
                      "loss": float(loss),
                      "finite": bool(np.isfinite(float(loss))),
                      "batch": live.batch, "seq": live.seq,
                      "engine": live.engine, "quantize": live.quantize})
        tr._switch_to(base)   # reset for the next rung
    return {"rungs": rungs, "devices": jax.device_count(),
            "mesh": _mesh_axes(tr.mesh)}


def task_probe(payload: dict) -> dict:
    """Topology-only: build a mesh on the emulated fleet and report its
    geometry (no model, no compile — cheap enough for edge-case tests)."""
    import jax

    from repro.runtime.elastic import make_mesh_from_devices

    mesh = make_mesh_from_devices(
        jax.devices(), payload.get("model_parallel", 1),
        pods=payload.get("pods", 1))
    return {"axis_names": list(mesh.axis_names), "mesh": _mesh_axes(mesh),
            "devices": jax.device_count()}


TASKS = {"train": task_train, "collectives": task_collectives,
         "elastic": task_elastic, "ladder": task_ladder,
         "probe": task_probe}


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if len(argv) != 2:
        print("usage: python -m repro.launch.fleet payload.json result.json",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        payload = json.load(f)
    try:
        result = TASKS[payload.get("task", "train")](payload)
        result["status"] = "ok"
    except Exception as e:   # report through the JSON channel, not the rc
        result = {"status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()}
    with open(argv[1], "w") as f:
        json.dump(result, f, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
