"""Production training launcher: MeSP LoRA fine-tuning with the full
substrate — sharded step, restartable data, atomic checkpoints, straggler
watchdog. On this container it runs real steps on small configs
(``--reduced``) and is the same code path the dry-run lowers for the
production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-0.5b \\
        --reduced --steps 100 --engine mesp --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import mebp, mesp, mezo, quant
from repro.data import make_batch_iterator
from repro.launch import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.models import model as model_lib
from repro.optim import make_optimizer
from repro.optim.schedules import constant
from repro.runtime.fault_tolerance import StragglerPolicy, run_resilient

log = logging.getLogger("repro.train")


def build_step(cfg, engine: str, opt, act_spec=None):
    if engine == "mezo":
        def step(params, opt_state, batch):
            key = jax.random.fold_in(jax.random.PRNGKey(0), opt_state["step"])
            loss, grads = mezo.spsa_grad(params, cfg, batch, key)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss
        return step

    mode = {"mesp": "structured", "mesp_pallas": "pallas", "mebp": "plain",
            "store_h": "store_h"}[engine]

    def step(params, opt_state, batch):
        loss, grads = mesp.value_and_grad(params, cfg, batch, mode=mode,
                                          act_spec=act_spec)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def build_arg_parser() -> argparse.ArgumentParser:
    """The launcher's CLI (importable: scripts/check_readme_flags.py keeps
    README.md honest against it)."""
    ap = argparse.ArgumentParser(prog="repro.launch.train")
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny same-family config (CPU-runnable)")
    ap.add_argument("--engine", default="mesp",
                    choices=["mesp", "mesp_pallas", "mebp", "mezo",
                             "store_h"],
                    help="mesp_pallas = MeSP with the fused Pallas kernel "
                         "path (interpret mode off-TPU)")
    ap.add_argument("--quantize", default="none", choices=list(quant.METHODS),
                    help="int8 = keep frozen base weights quantized "
                         "(per-output-channel symmetric); with "
                         "--engine mesp_pallas W0 is dequantized in VMEM, "
                         "other engines dequantize in the jnp graph")
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "sgd_momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=1)  # paper: batch 1
    ap.add_argument("--seq", type=int, default=256)  # paper: seq 256
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-interval", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_arg_parser().parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    log.info("arch=%s layers=%d d_model=%d engine=%s quantize=%s",
             cfg.name, cfg.n_layers, cfg.d_model, args.engine, args.quantize)

    opt = make_optimizer(args.optimizer, constant(args.lr))
    step_fn = jax.jit(build_step(cfg, args.engine, opt))

    it = make_batch_iterator(cfg.vocab, args.seq, args.batch,
                             host_index=jax.process_index(),
                             host_count=jax.process_count(),
                             seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir, interval=args.ckpt_interval)

    def init_state():
        params = model_lib.init_params(jax.random.PRNGKey(args.seed), cfg,
                                       quantize=args.quantize)
        return params, opt.init(params)

    t_last = [time.monotonic()]

    def on_step(res):
        if res.step % args.log_interval == 0:
            now = time.monotonic()
            log.info("step %5d  loss %.4f  %.3fs/step",
                     res.step, res.loss, res.seconds)
            t_last[0] = now

    params, opt_state, results = run_resilient(
        step_fn, init_state, it, ckpt, args.steps,
        straggler=StragglerPolicy(factor=10.0),
        on_step=on_step)
    log.info("done: final loss %.4f over %d steps",
             results[-1].loss, len(results))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
