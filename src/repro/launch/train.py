"""Production training launcher: MeSP LoRA fine-tuning with the full
substrate — sharded step, restartable data, atomic checkpoints, straggler
watchdog. On this container it runs real steps on small configs
(``--reduced``) and is the same code path the dry-run lowers for the
production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-0.5b \\
        --reduced --steps 100 --engine mesp --ckpt-dir /tmp/run1

The CLI is generated from ``repro.api``: ``--engine`` choices come from the
engine registry (registering a new engine adds it here with no edits to this
file) and the whole invocation round-trips through
:class:`repro.api.TrainSpec`. All run mechanics live in the
:class:`repro.api.Trainer` facade.
"""
from __future__ import annotations

import logging

from repro import telemetry
from repro.api import Trainer, TrainSpec
# re-exported: scripts/check_readme_flags.py and tests import the parser
# from here, its historical home
from repro.api import build_arg_parser  # noqa: F401

log = logging.getLogger("repro.train")


def main(argv=None):
    spec = TrainSpec.from_cli_args(argv).validate()

    logging.basicConfig(
        level=logging.WARNING if spec.quiet else logging.INFO)
    trainer = Trainer.from_spec(spec)
    cfg = trainer.cfg
    log.info("arch=%s layers=%d d_model=%d engine=%s quantize=%s",
             cfg.name, cfg.n_layers, cfg.d_model, spec.engine, spec.quantize)

    result = trainer.fit()
    # end-of-run reporting goes through the structured choke point
    # (repro.telemetry): per-step lines already did during fit
    telemetry.log_run_summary(result, quiet=spec.quiet)
    if result.degradations:
        fs = result.final_spec
        log.info("final spec after degradation: engine=%s batch=%d "
                 "seq=%d quantize=%s", fs.engine, fs.batch, fs.seq,
                 fs.quantize)
    if spec.telemetry == "on":
        log.info("telemetry: %s", result.metrics.get("telemetry_dir"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
