"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches JAX device state. The dry-run sets XLA_FLAGS for 512 host devices
*before* any JAX import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))
