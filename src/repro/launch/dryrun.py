from repro.launch.xla_flags import force_host_device_count

# Appends to XLA_FLAGS (user-set flags survive) and warns — instead of
# silently no-oping — when JAX already initialized in this process.
force_host_device_count(512)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without real hardware:
``jax.jit(step).lower(**specs).compile()`` must succeed on the 16×16
single-pod mesh AND the 2×16×16 multi-pod mesh for every assigned cell,
and emits ``memory_analysis()`` / ``cost_analysis()`` + the roofline terms
consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.api import ExecutionPolicy  # noqa: E402
from repro.configs import ASSIGNED, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.core import mesp  # noqa: E402
from repro.launch import inputs as inp  # noqa: E402
from repro.launch import sharding as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim import sgd  # noqa: E402
from repro.roofline import analyze  # noqa: E402


def build_train_fn(cfg, mesh, global_batch, *, backend="structured"):
    """(train_step, in_shardings, out_shardings) for jit."""
    opt = sgd(1e-4)
    policy = ExecutionPolicy(backend=backend,
                             act_spec=sh.activation_spec(mesh, global_batch))

    def train_step(params, opt_state, batch):
        loss, grads = mesp.value_and_grad(params, cfg, batch, policy=policy)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step, opt


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             backend: str = "structured", verbose: bool = True,
             act_override=None):
    """Lower + compile one cell. Returns a result dict (or skip record)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    t0 = time.monotonic()

    pstruct = inp.param_struct(cfg)
    pspecs = sh.param_specs(cfg, pstruct, mesh)
    pshard = sh.named(mesh, pspecs)

    with mesh:
        if shape.kind in ("train", "prefill"):
            batch_struct, batch_shard = inp.train_batch_specs(cfg, shape, mesh)
            if shape.kind == "train":
                step_fn, opt = build_train_fn(cfg, mesh, shape.global_batch,
                                              backend=backend)
                ostruct = jax.eval_shape(opt.init, pstruct)
                oshard = sh.named(mesh, sh.opt_specs(cfg, ostruct, mesh))
                lowered = jax.jit(
                    step_fn,
                    in_shardings=(pshard, oshard, batch_shard),
                    out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
                    donate_argnums=(0, 1),   # steady-state: update in place
                ).lower(pstruct, ostruct, batch_struct)
            else:  # prefill: forward pass producing logits
                act = (sh.activation_spec(mesh, shape.global_batch)
                       if act_override is None else act_override)
                policy = ExecutionPolicy(backend=backend, act_spec=act)

                def fwd(params, batch):
                    return model_lib.loss_fn(params, cfg, batch,
                                             policy=policy)

                lowered = jax.jit(
                    fwd,
                    in_shardings=(pshard, batch_shard),
                    out_shardings=NamedSharding(mesh, P()),
                ).lower(pstruct, batch_struct)
        else:  # decode
            cache_struct, cache_shard, tok, tok_shard = \
                inp.decode_input_specs(cfg, shape, mesh)

            def serve_step(params, cache, tokens):
                return model_lib.decode_step(params, cfg, cache, tokens)

            bspec = sh.batch_spec(mesh, shape.global_batch)
            bdim = bspec[0] if len(bspec) else None
            vdim = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
            logits_shard = NamedSharding(mesh, P(bdim, None, vdim))
            lowered = jax.jit(
                serve_step,
                in_shardings=(pshard, cache_shard, tok_shard),
                out_shardings=(logits_shard, cache_shard),
                donate_argnums=(1,),   # KV cache updates in place
            ).lower(pstruct, cache_struct, tok)

        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] memory_analysis:")
        print(f"  {ma}")
    report = analyze(cfg, shape, mesh_name, chips, compiled)
    if verbose:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print(f"  cost_analysis: flops={ca.get('flops', 0):.4g} "
              f"bytes={ca.get('bytes accessed', 0):.4g}")
        print(f"  roofline: compute={report.t_compute:.4g}s "
              f"memory={report.t_memory:.4g}s "
              f"collective={report.t_collective:.4g}s "
              f"dominant={report.dominant} "
              f"useful={report.useful_flops_ratio:.3f} "
              f"frac={report.roofline_fraction:.3f}")
    res = report.row()
    res.update({"status": "ok", "compile_s": time.monotonic() - t0,
                "coll_breakdown": report.coll_breakdown,
                "memory_analysis": str(ma)})
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cells = []
    archs = list(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failed = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                try:
                    r = run_cell(arch, shp, multi_pod=mp)
                except Exception as e:
                    failed += 1
                    r = {"arch": arch, "shape": shp,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    print(f"[{arch} × {shp}] FAILED: {r['error']}",
                          file=sys.stderr)
                results.append(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {failed} FAIL "
          f"of {len(results)} cells")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
