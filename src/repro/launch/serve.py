"""Serving launcher: a thin CLI over ``repro.serve``.

Two modes, picked by the model family:

* **Continuous batching** (dense/vlm): an :class:`~repro.serve.AdapterStore`
  holds ``--store-capacity`` resident tenants, requests round-robin over
  ``--adapters`` synthetic tenant adapters, and the
  :class:`~repro.serve.ContinuousBatcher` admits/recycles at step
  granularity with paged-KV accounting (tentpole path: grouped LoRA kernel
  under ``--engine mesp_pallas``).
* **Single-stream decode** (ssm/hybrid/audio/moe — no per-slot cache): the
  historical batched loop, one shared position for the whole batch.

Like ``launch/train.py``, the CLI is the registry-generated
:func:`repro.api.build_arg_parser` plus serve-only flags: the invocation is
a declarative :class:`repro.api.TrainSpec`, validated up front, and the
spec's :class:`~repro.api.ExecutionPolicy` is threaded through
``decode_step`` — so ``--quantize int8|int4|nf4`` serves against quantized
frozen weights (admission accounting follows via
``core/quant.weights_format``) and kernel/interpret overrides apply exactly
as in training.

Throughput discipline: a warmup pass is synced and *discarded* before the
timed region (compile + first-dispatch cost would otherwise deflate
steady-state tokens/s — same fix as the autotuner's timing loop).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-0.5b \\
        --reduced --adapters 4 --steps 32
"""
from __future__ import annotations

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecutionPolicy, TrainSpec, build_arg_parser
from repro.configs import get_config
from repro.core import quant
from repro.models import model as model_lib
from repro.serve import (AdapterStore, ContinuousBatcher, Request,
                         synthetic_adapters)

log = logging.getLogger("repro.serve")


class DecodeServer:
    """Single-stream batched decode (families without per-slot caches)."""

    def __init__(self, cfg, params, batch: int, max_len: int,
                 policy: ExecutionPolicy | None = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.policy = policy if policy is not None else ExecutionPolicy()
        self.cache = model_lib.init_cache(cfg, batch, max_len)
        if cfg.family == "audio":
            self.cache["enc_out"] = jnp.zeros(
                (batch, cfg.encdec.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        self._step = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t,
                                                  policy=self.policy))

    def step(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens [B,1] -> sampled next tokens [B,1] (greedy)."""
        logits, self.cache = self._step(self.params, self.cache, tokens)
        return jnp.argmax(logits, -1).astype(jnp.int32)


def _single_stream(cfg, params, spec, ns, policy) -> int:
    server = DecodeServer(cfg, params, spec.batch, ns.max_len, policy=policy)
    tok = jnp.ones((spec.batch, 1), jnp.int32)
    # warmup: compile + first dispatch, synced and discarded (not timed)
    tok = server.step(tok)
    jax.block_until_ready(tok)
    t0 = time.monotonic()
    outs = []
    for _ in range(spec.steps):
        tok = server.step(tok)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.monotonic() - t0
    log.info("decoded %d steps × %d seqs in %.3fs (%.1f tok/s steady-state)",
             spec.steps, spec.batch, dt, spec.steps * spec.batch / dt)
    log.info("sample: %s", [int(x) for x in outs[-1]])
    return 0


def _request_trace(n: int, adapters: list, prompt_len: int,
                   max_new: int) -> list:
    return [Request(f"r{i}", adapters[i % len(adapters)],
                    tuple(1 + (i + j) % 97 for j in range(prompt_len)),
                    max_new)
            for i in range(n)]


def _continuous(cfg, params, spec, ns, policy) -> int:
    store = AdapterStore(params, capacity=ns.store_capacity)
    bat = ContinuousBatcher(cfg, store, slots=spec.batch, tile=ns.tile,
                            max_len=ns.max_len, page_size=ns.page_size,
                            policy=policy, mem_budget_mb=ns.mem_budget_mb,
                            weights_fmt=quant.weights_format(spec.quantize))
    uids = [f"tenant{i}" for i in range(ns.adapters)]
    for i, uid in enumerate(uids):
        bat.register_adapter(uid, synthetic_adapters(params, spec.seed + i))

    # warmup: one request end-to-end, synced and discarded — compiles the
    # decode step so the timed trace measures steady-state serving
    bat.run([Request("warmup", uids[0], (1, 2, 3), 2)])
    for c in (bat.counters, store.counters, bat.alloc.counters):
        c.update({k: 0 for k in c})
    bat.results.clear()

    reqs = _request_trace(ns.requests, uids, ns.prompt_len, ns.max_new)
    t0 = time.monotonic()
    results = bat.run(reqs)
    jax.block_until_ready(bat.cache)
    dt = time.monotonic() - t0
    served = sum(len(v) for v in results.values())
    log.info("served %d requests / %d tokens across %d tenants in %.3fs "
             "(%.1f tok/s)", len(results), served, ns.adapters, dt,
             served / dt)
    log.info("batcher: %s", bat.counters)
    log.info("store:   %s (resident %d/%d, %.2f MB/slot)", store.counters,
             store.resident, store.capacity, store.slot_bytes / 2**20)
    log.info("pages:   %s (%d/%d used)", bat.alloc.counters,
             bat.alloc.used_pages, bat.alloc.n_pages)
    return 0


def main(argv=None):
    ap = build_arg_parser()
    ap.prog = "repro.launch.serve"
    # serve's historical defaults (32 decode steps × 4 sequences), not
    # TrainSpec's training defaults — bare invocations stay comparable with
    # pre-migration tok/s logs
    ap.set_defaults(batch=4, steps=32)
    ap.add_argument("--max-len", type=int, default=128,
                    help="serve-only: decode cache capacity per slot")
    ap.add_argument("--adapters", type=int, default=1,
                    help="serve-only: synthetic tenant adapters to serve")
    ap.add_argument("--store-capacity", type=int, default=None,
                    help="serve-only: resident adapter slots "
                         "(default: min(adapters, 4))")
    ap.add_argument("--tile", type=int, default=None,
                    help="serve-only: decode rows per adapter tile "
                         "(default: batch // 2, min 1)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="serve-only: KV tokens per allocator page")
    ap.add_argument("--requests", type=int, default=None,
                    help="serve-only: request-trace length "
                         "(default: 2 × adapters)")
    ap.add_argument("--prompt-len", type=int, default=4,
                    help="serve-only: synthetic prompt tokens per request")
    ap.add_argument("--max-new", type=int, default=None,
                    help="serve-only: tokens generated per request "
                         "(default: --steps)")
    ap.add_argument("--mem-budget-mb", type=float, default=None,
                    help="serve-only: admission headroom budget against "
                         "benchmarks/memsim.serve_residency")
    ns = ap.parse_args(argv)
    spec = TrainSpec.from_namespace(ns).validate()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(spec.arch)
    if spec.reduced:
        cfg = cfg.reduced()
    policy = spec.policy()
    params = model_lib.init_params(jax.random.PRNGKey(spec.seed), cfg,
                                   quantize=spec.quantize)
    log.info("arch=%s engine=%s quantize=%s backend=%s batch=%d adapters=%d",
             cfg.name, spec.engine, spec.quantize, policy.backend,
             spec.batch, ns.adapters)

    if cfg.family in ("dense", "vlm") and ns.adapters >= 1:
        if ns.store_capacity is None:
            ns.store_capacity = min(ns.adapters, 4)
        if ns.tile is None:
            ns.tile = max(spec.batch // 2, 1)
        if ns.requests is None:
            ns.requests = 2 * ns.adapters
        if ns.max_new is None:
            ns.max_new = spec.steps
        if ns.prompt_len + ns.max_new > ns.max_len:
            ap.error(f"--prompt-len + --max-new ({ns.prompt_len}+"
                     f"{ns.max_new}) exceeds --max-len {ns.max_len}")
        return _continuous(cfg, params, spec, ns, policy)
    if ns.adapters > 1:
        ap.error(f"--adapters > 1 needs a dense/vlm arch "
                 f"(got family {cfg.family!r})")
    return _single_stream(cfg, params, spec, ns, policy)


if __name__ == "__main__":
    raise SystemExit(main())
