"""Batched serving driver: prefill-free cached decode with request batching.

Demonstrates the serve path that ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token per step against a persistent KV cache / recurrent
state. Requests are greedily batched; finished sequences are recycled
(continuous batching at step granularity).

Like ``launch/train.py``, the CLI is the registry-generated
:func:`repro.api.build_arg_parser` (plus serve-only ``--max-len``): the
invocation is a declarative :class:`repro.api.TrainSpec`, validated up
front (engine × quantize coherence), and the spec's
:class:`~repro.api.ExecutionPolicy` is threaded through ``decode_step`` —
so ``--quantize int8`` serves against int8 frozen weights and
kernel/interpret overrides apply exactly as they do in training.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
        --batch 4 --steps 32
"""
from __future__ import annotations

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecutionPolicy, TrainSpec, build_arg_parser
from repro.configs import get_config
from repro.models import model as model_lib

log = logging.getLogger("repro.serve")


class DecodeServer:
    def __init__(self, cfg, params, batch: int, max_len: int,
                 policy: ExecutionPolicy | None = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.policy = policy if policy is not None else ExecutionPolicy()
        self.cache = model_lib.init_cache(cfg, batch, max_len)
        if cfg.family == "audio":
            self.cache["enc_out"] = jnp.zeros(
                (batch, cfg.encdec.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        self._step = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t,
                                                  policy=self.policy))

    def step(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens [B,1] -> sampled next tokens [B,1] (greedy)."""
        logits, self.cache = self._step(self.params, self.cache, tokens)
        return jnp.argmax(logits, -1).astype(jnp.int32)


def main(argv=None):
    ap = build_arg_parser()
    ap.prog = "repro.launch.serve"
    # serve's historical defaults (32 decode steps × 4 sequences), not
    # TrainSpec's training defaults — bare invocations stay comparable with
    # pre-migration tok/s logs
    ap.set_defaults(batch=4, steps=32)
    ap.add_argument("--max-len", type=int, default=128,
                    help="serve-only: decode cache capacity")
    ns = ap.parse_args(argv)
    spec = TrainSpec.from_namespace(ns).validate()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(spec.arch)
    if spec.reduced:
        cfg = cfg.reduced()
    policy = spec.policy()
    params = model_lib.init_params(jax.random.PRNGKey(spec.seed), cfg,
                                   quantize=spec.quantize)
    server = DecodeServer(cfg, params, spec.batch, ns.max_len, policy=policy)
    log.info("arch=%s engine=%s quantize=%s backend=%s batch=%d",
             cfg.name, spec.engine, spec.quantize, policy.backend, spec.batch)

    tok = jnp.ones((spec.batch, 1), jnp.int32)
    t0 = time.monotonic()
    outs = []
    for i in range(spec.steps):
        tok = server.step(tok)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.monotonic() - t0
    log.info("decoded %d steps × %d seqs in %.3fs (%.1f tok/s)",
             spec.steps, spec.batch, dt, spec.steps * spec.batch / dt)
    log.info("sample: %s", [int(x) for x in outs[-1]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
