"""Batched serving driver: prefill-free cached decode with request batching.

Demonstrates the serve path that ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token per step against a persistent KV cache / recurrent
state. Requests are greedily batched; finished sequences are recycled
(continuous batching at step granularity).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
        --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib

log = logging.getLogger("repro.serve")


class DecodeServer:
    def __init__(self, cfg, params, batch: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache = model_lib.init_cache(cfg, batch, max_len)
        if cfg.family == "audio":
            self.cache["enc_out"] = jnp.zeros(
                (batch, cfg.encdec.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        self._step = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t))

    def step(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens [B,1] -> sampled next tokens [B,1] (greedy)."""
        logits, self.cache = self._step(self.params, self.cache, tokens)
        return jnp.argmax(logits, -1).astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    server = DecodeServer(cfg, params, args.batch, args.max_len)

    tok = jnp.ones((args.batch, 1), jnp.int32)
    t0 = time.monotonic()
    outs = []
    for i in range(args.steps):
        tok = server.step(tok)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.monotonic() - t0
    log.info("decoded %d steps × %d seqs in %.3fs (%.1f tok/s)",
             args.steps, args.batch, dt, args.steps * args.batch / dt)
    log.info("sample: %s", [int(x) for x in outs[-1]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
