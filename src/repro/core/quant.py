"""Quantized frozen base weights: int8 and packed sub-8-bit (int4 / nf4).

The paper keeps base weights 4-bit quantized (QLoRA-style) and dequantizes on
the fly (§4.5). The TPU MXU has no sub-8-bit datapath, so the 4-bit formats
store two nibbles per byte along the input dimension and unpack on the VPU in
front of the MXU (shift/mask + sign-extend for ``int4``, 16-entry codebook
lookup for ``nf4``); int8 remains the native-width path. In every quantized
mode the dense float W0 exists only inside kernel VMEM — never in HBM
(jaxpr-asserted in ``tests/test_quant_mode.py``).

Only *frozen* weights quantize; LoRA factors stay bf16 (they are trained).
The LoRA gradients are unaffected: the structured backward needs x and the
dequantized W0 only through ``g @ W0ᵀ``, which uses the same dequant.

Leaf formats produced by :func:`quantize_frozen` (plain dicts, so every
path-keyed subsystem — checkpointer, sharding, adapter store, degradation
ladder — composes without special cases):

* int8:  ``{"q": int8 [..., K, N], "scale": f32 [..., 1, N]}``
* int4:  ``{"q4": uint8 [..., ceil(K/2), N], "scale": f32 [..., 1, N]}``
* nf4:   int4 layout plus ``"code": f32 [..., 16]`` (the dequant codebook —
  its presence is also the method discriminator)

``q4`` byte row ``j`` packs input rows ``2j`` (low nibble) and ``2j+1``
(high nibble). Odd K pads the final high nibble with the encoding of 0.0
(``0`` for int4 two's-complement, codebook index 7 for nf4) and adds a
``"kpad": uint8 [..., 1]`` marker leaf whose *presence* records the parity,
so the original K stays statically recoverable from the pytree alone. The
``code``/``kpad`` leaves broadcast over the weight's leading batch dims so
stacked block trees ([L, K, N] leaves) keep a uniform scan axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Normal-float-4 codebook (QLoRA §3.1): the 16 quantiles of N(0, 1)
#: renormalized to [-1, 1], with an exact zero at index 7. Kernels bake these
#: constants in; the tree carries a copy in the leaf for oracle dequant.
NF4_CODE = (
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
)
#: Nibble value that dequantizes to 0.0 in each packed format (odd-K pad).
INT4_ZERO_NIBBLE = 0
NF4_ZERO_NIBBLE = 7


def quantize_int8(w: jax.Array):
    """w: [..., d_in, d_out] -> (q: int8 same shape, scale: [..., 1, d_out])."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------- 4-bit pack
def pack_nibbles(nibbles: jax.Array, *, pad_value: int = 0) -> jax.Array:
    """[..., K, N] nibble values (0..15) -> [..., ceil(K/2), N] uint8.

    Byte row ``j`` holds input row ``2j`` in the low nibble and ``2j+1`` in
    the high nibble; odd K appends one ``pad_value`` nibble."""
    k = nibbles.shape[-2]
    if k % 2:
        pad = jnp.full(nibbles.shape[:-2] + (1, nibbles.shape[-1]),
                       pad_value, jnp.uint8)
        nibbles = jnp.concatenate([nibbles.astype(jnp.uint8), pad], axis=-2)
    v = nibbles.astype(jnp.uint8)
    lo, hi = v[..., 0::2, :], v[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array, k: int | None = None) -> jax.Array:
    """[..., ceil(K/2), N] uint8 -> [..., K, N] int32 nibble values (0..15).

    ``k`` slices off the odd-K pad nibble; ``None`` returns all ``2*rows``."""
    v = packed.astype(jnp.int32)
    lo, hi = v & 0xF, v >> 4
    both = jnp.stack([lo, hi], axis=-2)          # [..., rows, 2, N]
    out = both.reshape(*packed.shape[:-2], -1, packed.shape[-1])
    return out if k is None else out[..., :k, :]


def sign_extend4(nibbles: jax.Array) -> jax.Array:
    """Two's-complement sign extension of 4-bit values held in int32."""
    return (nibbles ^ 8) - 8


def quantize_int4(w: jax.Array):
    """w: [..., K, N] -> (q4: uint8 [..., ceil(K/2), N], scale [..., 1, N]).

    Symmetric per-output-channel: q ∈ [-7, 7], scale = absmax / 7."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 7.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -7, 7)
    q4 = pack_nibbles(q.astype(jnp.int32) & 0xF, pad_value=INT4_ZERO_NIBBLE)
    return q4, scale.astype(jnp.float32)


def quantize_nf4(w: jax.Array):
    """w: [..., K, N] -> (q4: uint8 [..., ceil(K/2), N], scale [..., 1, N]).

    Per-output-channel absmax scaling to [-1, 1], then nearest-neighbour
    assignment into the sorted :data:`NF4_CODE` book via its midpoints."""
    code = jnp.asarray(NF4_CODE, jnp.float32)
    mids = (code[1:] + code[:-1]) / 2.0
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8)
    idx = jnp.searchsorted(mids, w.astype(jnp.float32) / scale)
    q4 = pack_nibbles(idx.astype(jnp.int32), pad_value=NF4_ZERO_NIBBLE)
    return q4, scale.astype(jnp.float32)


def dequantize_packed(q4: jax.Array, scale: jax.Array, method: str,
                      dtype=jnp.bfloat16, k: int | None = None):
    """Packed q4 + scale -> dense [..., K, N] weights (the oracle path)."""
    nib = unpack_nibbles(q4, k)
    if method == "int4":
        w = sign_extend4(nib).astype(jnp.float32)
    elif method == "nf4":
        w = jnp.asarray(NF4_CODE, jnp.float32)[nib]
    else:
        raise ValueError(f"unknown packed method {method!r}")
    return (w * scale).astype(dtype)


# ------------------------------------------------------------- leaf formats
def quantize_leaf(w: jax.Array, method: str):
    """Dense frozen weight -> quantized leaf dict for ``method``."""
    if method == "int8":
        q, s = quantize_int8(w)
        return {"q": q, "scale": s}
    if method in ("int4", "nf4"):
        q4, s = (quantize_int4 if method == "int4" else quantize_nf4)(w)
        leaf = {"q4": q4, "scale": s}
        # code/kpad broadcast over w's leading batch dims (stacked block
        # leaves are [L, K, N] and jax.lax.scan needs every leaf in the
        # stacked tree to share the leading axis)
        batch = w.shape[:-2]
        if method == "nf4":
            leaf["code"] = jnp.broadcast_to(
                jnp.asarray(NF4_CODE, jnp.float32), batch + (16,))
        if w.shape[-2] % 2:
            leaf["kpad"] = jnp.ones(batch + (1,), jnp.uint8)
        return leaf
    raise ValueError(f"unknown quantize method {method!r}; "
                     f"expected one of {METHODS[1:]}")


def is_quantized(p) -> bool:
    """True for a ``{"q", "scale"}`` int8 quantized-weight leaf."""
    return isinstance(p, dict) and "q" in p and "scale" in p


def is_packed(p) -> bool:
    """True for a packed 4-bit ``{"q4", "scale"}`` quantized-weight leaf."""
    return isinstance(p, dict) and "q4" in p and "scale" in p


def packed_method(p) -> str:
    """"int4" or "nf4" for a packed leaf (the codebook is the marker)."""
    return "nf4" if "code" in p else "int4"


def packed_k(p) -> int:
    """Original (unpacked) input dimension of a packed leaf."""
    return 2 * p["q4"].shape[-2] - (1 if "kpad" in p else 0)


def maybe_dequant(p, dtype=jnp.bfloat16):
    """Resolve a (possibly quantized) linear weight leaf to a dense matrix."""
    if is_packed(p):
        return dequantize_packed(p["q4"], p["scale"], packed_method(p),
                                 dtype, k=packed_k(p))
    if is_quantized(p):
        return dequantize_int8(p["q"], p["scale"], dtype)
    return p


def add_group_axis(p):
    """Expand a shared quantized base leaf with a leading group axis of 1
    (the grouped-decode path's broadcast; ``code``/``kpad`` carry no group
    axis and pass through)."""
    if is_packed(p):
        out = dict(p, q4=p["q4"][None], scale=p["scale"][None])
        return out
    return {"q": p["q"][None], "scale": p["scale"][None]}


def quantize_frozen(params, *, method: str = "int8",
                    skip_keys=("a", "b", "bias")):
    """Quantize every frozen ≥2-D weight leaf; returns a new pytree where
    quantized leaves become format dicts (see module docstring). Leaves that
    are *already* quantized are dequantized and re-quantized, so the
    degradation ladder's int8 → int4 transition is a plain re-call."""
    def one(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        if keys and keys[-1] in skip_keys:
            return leaf
        if is_quantized(leaf) or is_packed(leaf):
            leaf = maybe_dequant(leaf, jnp.float32)
        if getattr(leaf, "ndim", 0) >= 2 and keys and keys[-1] == "w":
            return quantize_leaf(leaf, method)
        return leaf

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda p: is_quantized(p) or is_packed(p))


#: ``--quantize`` values accepted by the launchers / init_params.
METHODS = ("none", "int8", "int4", "nf4")


def weights_format(method) -> str:
    """Map a ``--quantize`` method to the memsim/serve weights-format row.

    The single choke point for format resolution: an unknown method raises
    instead of silently falling back to bf16 accounting."""
    m = "none" if method is None else method
    if m not in METHODS:
        raise ValueError(f"unknown quantize method {method!r}; "
                         f"expected one of {METHODS}")
    return "bf16" if m == "none" else m


def quantize_params(params, method):
    """Apply a named quantization method to a param pytree (None/"none" is a
    no-op). The single entry point behind ``launch/train.py --quantize``."""
    if method is None or method == "none":
        return params
    if method in ("int8", "int4", "nf4"):
        return quantize_frozen(params, method=method)
    raise ValueError(f"unknown quantize method {method!r}; "
                     f"expected one of {METHODS}")
