"""int8 frozen-weight quantization with on-the-fly dequantization.

The paper keeps base weights 4-bit quantized (QLoRA-style) and dequantizes on
the fly (§4.5). TPUs have no native 4-bit datapath; the TPU-idiomatic
equivalent is int8 symmetric per-output-channel quantization — weights halve
HBM footprint/traffic vs bf16 and dequantize on the VPU in front of the MXU
(DESIGN.md §2).

Only *frozen* weights quantize; LoRA factors stay bf16 (they are trained).
The LoRA gradients are unaffected: the structured backward needs x and the
dequantized W0 only through ``g @ W0ᵀ``, which uses the same dequant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(w: jax.Array):
    """w: [..., d_in, d_out] -> (q: int8 same shape, scale: [..., 1, d_out])."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_frozen(params, *, skip_keys=("a", "b", "bias")):
    """Quantize every frozen ≥2-D weight leaf; returns a new pytree where
    quantized leaves become {"q": int8, "scale": f32} dicts."""
    def one(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        if keys and keys[-1] in skip_keys:
            return leaf
        if getattr(leaf, "ndim", 0) >= 2 and keys and keys[-1] == "w":
            q, s = quantize_int8(leaf)
            return {"q": q, "scale": s}
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


def is_quantized(p) -> bool:
    """True for a ``{"q", "scale"}`` quantized-weight leaf."""
    return isinstance(p, dict) and "q" in p and "scale" in p


def maybe_dequant(p, dtype=jnp.bfloat16):
    """Resolve a (possibly quantized) linear weight leaf to a dense matrix."""
    if is_quantized(p):
        return dequantize_int8(p["q"], p["scale"], dtype)
    return p


#: ``--quantize`` values accepted by the launchers / init_params.
METHODS = ("none", "int8")


def quantize_params(params, method):
    """Apply a named quantization method to a param pytree (None/"none" is a
    no-op). The single entry point behind ``launch/train.py --quantize``."""
    if method is None or method == "none":
        return params
    if method == "int8":
        return quantize_frozen(params)
    raise ValueError(f"unknown quantize method {method!r}; "
                     f"expected one of {METHODS}")
