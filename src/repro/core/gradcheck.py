"""Gradient-quality analysis (paper §5.6, Table 3).

Compares a gradient estimate against the exact gradient per layer:
cosine similarity, sign agreement, relative error. Reproduces the paper's
finding that MeZO estimates are essentially uncorrelated with true gradients
(cos ≈ 0.001, sign agreement ≈ 50%).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp


def _flat_concat(tree) -> jax.Array:
    leaves = [l.reshape(-1).astype(jnp.float32)
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.concatenate(leaves) if leaves else jnp.zeros((0,), jnp.float32)


def gradient_metrics(g_est, g_true) -> Dict[str, jax.Array]:
    """cosine similarity / sign agreement / relative error over a pytree."""
    a, b = _flat_concat(g_est), _flat_concat(g_true)
    na = jnp.linalg.norm(a)
    nb = jnp.linalg.norm(b)
    cos = jnp.dot(a, b) / jnp.maximum(na * nb, 1e-30)
    sign = jnp.mean((jnp.sign(a) == jnp.sign(b)).astype(jnp.float32))
    rel = jnp.linalg.norm(a - b) / jnp.maximum(nb, 1e-30)
    return {"cosine_sim": cos, "sign_agree": sign, "rel_error": rel}


def per_layer_metrics(g_est_blocks, g_true_blocks, n_layers: int) -> List[dict]:
    """Table 3: metrics per transformer layer (stacked block grads [L,...])."""
    out = []
    for i in range(n_layers):
        gi = jax.tree_util.tree_map(lambda t: t[i], g_est_blocks)
        ti = jax.tree_util.tree_map(lambda t: t[i], g_true_blocks)
        m = gradient_metrics(gi, ti)
        out.append({k: float(v) for k, v in m.items()} | {"layer": i})
    return out
