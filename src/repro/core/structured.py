"""Hand-derived structured backward passes (paper §4.2, Appendix A).

Every op here is a ``jax.custom_vjp`` whose **residual tuple is the
tensor-lifecycle contract**: what is in the residuals is what survives the
forward pass; everything else is freed by XLA and recomputed on-demand in the
backward pass. This is the JAX-native expression of MeSP's "manually derived
backward passes with explicit control over tensor lifecycles".

The key primitive is :func:`lora_linear`, which — unlike autodiff — does NOT
save the intermediate projection ``h = x @ A`` (shape [..., r]); it recomputes
it in backward from ``x`` (which must be saved anyway, being needed for
``dA``) at cost O(b·n·d_in·r) ≪ the cost of storing h across all LoRA layers
(paper §4.1, Table 5).

All derivations follow paper Appendix A and are verified against
``jax.grad`` of the plain-jnp references in ``tests/test_structured.py``
(mathematical-equivalence claim, paper §5.5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _flat2(x: Array) -> Array:
    """Collapse all leading dims: [..., d] -> [prod(...), d]."""
    return x.reshape(-1, x.shape[-1])


def _zero_cot(x):
    """Zero cotangent matching JAX's convention (float0 for int/bool leaves)."""
    import numpy as np

    if x is None:
        return None
    if isinstance(x, int):
        return np.zeros((), dtype=jax.dtypes.float0)
    if jnp.issubdtype(jnp.result_type(x), jnp.integer) or \
            jnp.result_type(x) == jnp.bool_:
        return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)
    return jnp.zeros_like(x)


# ---------------------------------------------------------------------------
# LoRA linear — the paper's core op (Appendix A.1)
#
#   y = x @ W0 + s * (x @ A) @ B           h := x @ A   (NOT stored)
#
#   dB = h^T (s g)          (A.1 eq 10)    <- h recomputed here
#   dh = (s g) B^T          (A.1 eq 11)
#   dA = x^T dh             (A.1 eq 12)
#   dx = dh A^T + g W0^T    (A.1 eq 13)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def lora_linear(x, w0, a, b, bias, scale: float):
    """LoRA-adapted linear: ``x @ w0 + scale * (x @ a) @ b [+ bias]``.

    ``w0``/``bias`` are frozen (their cotangents are symbolic zeros that XLA
    dead-code-eliminates); ``a``/``b`` are the trainable LoRA factors.
    """
    y = x @ w0 + scale * ((x @ a) @ b)
    if bias is not None:
        y = y + bias
    return y


def _lora_linear_fwd(x, w0, a, b, bias, scale):
    # MeSP residuals: x only — h = x@a is deliberately NOT saved.
    y = x @ w0 + scale * ((x @ a) @ b)
    if bias is not None:
        y = y + bias
    return y, (x, w0, a, b, bias is not None)


def _lora_linear_bwd(scale, res, g):
    x, w0, a, b, has_bias = res
    gx = g.astype(x.dtype)
    sg = scale * gx
    swap = lambda m: jnp.swapaxes(m, -1, -2)
    dh = sg @ swap(b)                                # (A.1 eq 11)
    h = x @ a                                        # recompute (paper §4.1)
    if w0.ndim == 2:
        # shared weight: flatten leading dims into one big contraction
        db = _flat2(h).T @ _flat2(sg)                # (A.1 eq 10)
        da = _flat2(x).T @ _flat2(dh)                # (A.1 eq 12)
    else:
        # per-expert batched weights (MoE EP): x [E,C,d], w0/a/b [E,·,·]
        db = swap(h) @ sg
        da = swap(x) @ dh
    dx = dh @ swap(a) + gx @ swap(w0)                # (A.1 eq 13)
    dw0 = jnp.zeros_like(w0)                         # frozen; DCE'd by XLA
    dbias = jnp.zeros(w0.shape[-1], w0.dtype) if has_bias else None
    return (dx, dw0, da.astype(a.dtype), db.astype(b.dtype), dbias)


lora_linear.defvjp(_lora_linear_fwd, _lora_linear_bwd)


# Ablation variant (paper §5.7 / Table 5): identical math, but h IS stored.
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def lora_linear_store_h(x, w0, a, b, bias, scale: float):
    y = x @ w0 + scale * ((x @ a) @ b)
    if bias is not None:
        y = y + bias
    return y


def _lora_store_fwd(x, w0, a, b, bias, scale):
    h = x @ a
    y = x @ w0 + scale * (h @ b)
    if bias is not None:
        y = y + bias
    return y, (x, w0, a, b, h, bias is not None)   # <- h in residuals


def _lora_store_bwd(scale, res, g):
    x, w0, a, b, h, has_bias = res
    gx = g.astype(x.dtype)
    sg = scale * gx
    swap = lambda m: jnp.swapaxes(m, -1, -2)
    dh = sg @ swap(b)
    if w0.ndim == 2:
        db = _flat2(h).T @ _flat2(sg)
        da = _flat2(x).T @ _flat2(dh)
    else:
        db = swap(h) @ sg
        da = swap(x) @ dh
    dx = dh @ swap(a) + gx @ swap(w0)
    dbias = jnp.zeros(w0.shape[-1], w0.dtype) if has_bias else None
    return (dx, jnp.zeros_like(w0), da.astype(a.dtype), db.astype(b.dtype), dbias)


lora_linear_store_h.defvjp(_lora_store_fwd, _lora_store_bwd)


# ---------------------------------------------------------------------------
# RMSNorm (Appendix A.3)
#
#   rms = sqrt(mean(x^2) + eps);  xhat = x / rms;  y = xhat * w
#   dxhat = g * w
#   dx = (dxhat - xhat * mean(dxhat ⊙ xhat)) / rms     (A.3 eq 22)
#   dw = sum_batch(g ⊙ xhat)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, w, eps: float = 1e-6):
    rms = jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True) + eps)
    return ((x.astype(jnp.float32) / rms) * w.astype(jnp.float32)).astype(x.dtype)


def _rmsnorm_fwd(x, w, eps):
    # Residual: x only. rms/xhat recomputed in backward (one reduction).
    return rmsnorm(x, w, eps), (x, w)


def _rmsnorm_bwd(eps, res, g):
    x, w = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    xhat = xf / rms
    dxhat = gf * w.astype(jnp.float32)
    dx = (dxhat - xhat * jnp.mean(dxhat * xhat, -1, keepdims=True)) / rms
    dw = jnp.sum(_flat2(gf) * _flat2(xhat), 0)
    return dx.astype(x.dtype), dw.astype(w.dtype)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


# ---------------------------------------------------------------------------
# SiLU (Appendix A.4):  silu(x) = x σ(x);  silu'(x) = σ(x)(1 + x(1-σ(x)))
# Residual: x only — σ(x) recomputed.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def silu(x):
    return x * jax.nn.sigmoid(x)


def _silu_fwd(x):
    return x * jax.nn.sigmoid(x), (x,)


def _silu_bwd(res, g):
    (x,) = res
    s = jax.nn.sigmoid(x)
    return (g * s * (1 + x * (1 - s)),)


silu.defvjp(_silu_fwd, _silu_bwd)


# GeLU (tanh approx) for whisper — same recompute-from-x discipline.
@jax.custom_vjp
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def _gelu_fwd(x):
    return jax.nn.gelu(x, approximate=True), (x,)


def _gelu_bwd(res, g):
    (x,) = res
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    t = jnp.tanh(c * (x + 0.044715 * x**3))
    dt = (1 - t * t) * c * (1 + 3 * 0.044715 * x * x)
    return (g * (0.5 * (1 + t) + 0.5 * x * dt),)


gelu.defvjp(_gelu_fwd, _gelu_bwd)


# ---------------------------------------------------------------------------
# Scaled-dot-product attention (Appendix A.2), GQA + causal/windowed masking.
#
# Forward: probs = softmax(q k^T / sqrt(d) + mask);  out = probs v
# Residuals: (q, k, v) ONLY — the [*, n, n] probability matrix is recomputed
# in backward (FlashAttention principle, paper §2). Softmax backward:
#   dscores = probs ⊙ (dprobs − sum(dprobs ⊙ probs, -1))      (A.2 eq 19)
# ---------------------------------------------------------------------------


def _attn_mask(n_q: int, n_k: int, window: int, causal: bool, q_offset) -> Array:
    """[n_q, n_k] additive mask. q position i sits at absolute q_offset+i.
    ``q_offset`` may be a per-batch-row vector [B] (continuous-batching
    decode, every slot at its own position) — the mask then gains a leading
    batch dim: [B, n_q, n_k]."""
    off = jnp.asarray(q_offset)
    qpos = (off[..., None] if off.ndim else off) + jnp.arange(n_q)
    kpos = jnp.arange(n_k)
    d = qpos[..., :, None] - kpos
    ok = jnp.ones(d.shape, jnp.bool_)
    if causal:
        ok = ok & (d >= 0)
    if window > 0:
        ok = ok & (d < window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _sdpa_mask(Nq: int, Nk: int, window: int, causal: bool, q_offset,
               kv_len) -> Array:
    """Combined positional + valid-length mask, broadcastable against
    [B, Hkv, G, Nq, Nk] scores. Scalar q_offset/kv_len keep the historical
    [Nq, Nk]-shaped mask; per-row vectors lift it to [B, 1, 1, Nq, Nk]."""
    mask = _attn_mask(Nq, Nk, window, causal, q_offset)
    if mask.ndim == 3:
        mask = mask[:, None, None]                    # [B,1,1,Nq,Nk]
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        km = jnp.where(jnp.arange(Nk) < kvl[..., None], 0.0, -jnp.inf)
        if km.ndim == 2:                              # [B,Nk] per-row lengths
            km = km[:, None, None, None]
        mask = mask + km
    return mask


def _sdpa_ref(q, k, v, window: int, causal: bool, q_offset, kv_len):
    """Plain forward. q:[B,H,Nq,D] k,v:[B,Hkv,Nk,D] -> [B,H,Nq,D].

    Matmuls run on native (bf16) operands with f32 accumulation
    (``preferred_element_type``) — no materialized f32 copy of K/V, which for
    decode would double-read the whole KV cache (§Perf iteration 1).
    """
    B, H, Nq, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Nq, D)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / jnp.sqrt(D)
    # decode: only the first kv_len cache slots are valid (kv_len/q_offset
    # may be per-row vectors — continuous batching)
    scores = scores + _sdpa_mask(Nq, k.shape[2], window, causal, q_offset,
                                 kv_len)
    probs = jax.nn.softmax(scores, -1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Nq, D).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def sdpa(q, k, v, window: int = 0, causal: bool = True,
         q_offset: Array | int = 0, kv_len: Optional[Array] = None):
    return _sdpa_ref(q, k, v, window, causal, q_offset, kv_len)


def _sdpa_fwd(q, k, v, window, causal, q_offset, kv_len):
    out = _sdpa_ref(q, k, v, window, causal, q_offset, kv_len)
    return out, (q, k, v, q_offset, kv_len)  # probs NOT saved


def _sdpa_bwd(window, causal, res, g):
    q, k, v, q_offset, kv_len = res
    B, H, Nq, D = q.shape
    Hkv = k.shape[1]
    Nk = k.shape[2]
    G = H // Hkv
    f32 = dict(preferred_element_type=jnp.float32)
    qg = q.reshape(B, Hkv, G, Nq, D)
    gg = g.reshape(B, Hkv, G, Nq, D).astype(q.dtype)
    # --- recompute probs (A.2 forward) ---
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k, **f32) / jnp.sqrt(D)
    mask = _sdpa_mask(Nq, Nk, window, causal, q_offset, kv_len)
    probs = jax.nn.softmax(scores + mask, -1)
    pl = probs.astype(q.dtype)
    # --- A.2 eqs 17-21 ---
    dv = jnp.einsum("bhgqk,bhgqd->bhkd", pl, gg, **f32)           # eq 17 (GQA-summed)
    dprobs = jnp.einsum("bhgqd,bhkd->bhgqk", gg, v, **f32)        # eq 18
    dscores = probs * (dprobs - jnp.sum(dprobs * probs, -1, keepdims=True))  # eq 19
    dsl = dscores.astype(q.dtype)
    dq = jnp.einsum("bhgqk,bhkd->bhgqd", dsl, k, **f32) / jnp.sqrt(D)  # eq 20
    dk = jnp.einsum("bhgqk,bhgqd->bhkd", dsl, qg, **f32) / jnp.sqrt(D)  # eq 21
    dq = dq.reshape(B, H, Nq, D).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), _zero_cot(q_offset), _zero_cot(kv_len)


sdpa.defvjp(_sdpa_fwd, _sdpa_bwd)


# ---------------------------------------------------------------------------
# Cross-entropy with hand-derived backward: residuals are (logits-max stats),
# not the [B,N,V] softmax. For big-vocab archs this is a large saving.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Mean token cross-entropy; positions with label == -1 are ignored.

    logits [B,N,V] (any dtype), labels [B,N] int.
    """
    lf = logits.astype(jnp.float32)
    valid = (labels >= 0)
    safe = jnp.where(valid, labels, 0)
    lse = jax.scipy.special.logsumexp(lf, -1)
    ll = jnp.take_along_axis(lf, safe[..., None], -1)[..., 0]
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum((lse - ll) * valid) / n


def _xent_fwd(logits, labels):
    return softmax_xent(logits, labels), (logits, labels)


def _xent_bwd(res, g):
    logits, labels = res
    lf = logits.astype(jnp.float32)
    valid = (labels >= 0)
    safe = jnp.where(valid, labels, 0)
    p = jax.nn.softmax(lf, -1)                      # recomputed
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=jnp.float32)
    n = jnp.maximum(jnp.sum(valid), 1)
    dlogits = (g / n) * (p - onehot) * valid[..., None]
    return dlogits.astype(logits.dtype), _zero_cot(labels)


softmax_xent.defvjp(_xent_fwd, _xent_bwd)
