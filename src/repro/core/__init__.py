"""MeSP core: structured backward passes + training engines + baselines."""
from repro.core import flash, gradcheck, mebp, mesp, mezo, quant, structured  # noqa: F401
