"""MeZO baseline (paper §3.2): SPSA zeroth-order gradient estimation.

Two forward passes with ±ε z perturbations of the LoRA parameters; the
projected-gradient scalar scales z as the update direction. As in the MeZO
paper, the perturbation is regenerated from the seed instead of stored
(inference-level memory). Gradient-quality metrics for Table 3 live in
``core.gradcheck``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.api.policy import PLAIN
from repro.configs.base import ArchConfig
from repro.models import model as model_lib


def _perturb(train, key, eps_signed):
    """p + eps·z with z ~ N(0, I), z regenerated from key (not stored)."""
    leaves, treedef = jax.tree_util.tree_flatten(train)
    keys = jax.random.split(key, len(leaves))
    out = [p + eps_signed * jax.random.normal(k, p.shape, p.dtype)
           for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def spsa_grad(params, cfg: ArchConfig, batch: dict, key, eps: float = 1e-3):
    """MeZO gradient estimate over LoRA params: ((L+ − L−)/2ε) · z."""
    train, frozen = model_lib.split_params(params)

    def loss(t):
        return model_lib.loss_fn(model_lib.merge_params(t, frozen), cfg, batch,
                                 policy=PLAIN)

    l_plus = loss(_perturb(train, key, +eps))
    l_minus = loss(_perturb(train, key, -eps))
    proj = (l_plus - l_minus) / (2.0 * eps)

    leaves, treedef = jax.tree_util.tree_flatten(train)
    keys = jax.random.split(key, len(leaves))
    grads = [proj.astype(p.dtype) * jax.random.normal(k, p.shape, p.dtype)
             for p, k in zip(leaves, keys)]
    grad_tree = jax.tree_util.tree_unflatten(treedef, grads)
    return 0.5 * (l_plus + l_minus), grad_tree


def train_step(params, cfg: ArchConfig, batch: dict, key, lr: float,
               eps: float = 1e-3):
    loss, grads = spsa_grad(params, cfg, batch, key, eps)
    train, frozen = model_lib.split_params(params)
    new_train = jax.tree_util.tree_map(lambda p, g: p - lr * g, train, grads)
    return model_lib.merge_params(new_train, frozen), loss
