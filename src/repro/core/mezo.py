"""MeZO baseline (paper §3.2) — compatibility shim over ``repro.zo``.

The actual estimator machinery lives in the pluggable zeroth-order
subsystem: ``repro.zo.samplers`` (probe distributions), ``repro.zo.
estimator`` (the generic multi-query SPSA loop) and ``repro.zo.engines``
(the ``mezo*`` engine registrations). This module keeps the historical
``core.mezo.spsa_grad`` / ``train_step`` entry points alive with their
original signatures and **bit-identical** results (dense sampler, one
query — pinned by tests/test_zo.py's shim-equivalence test).

Gradient-quality metrics for Table 3 live in ``core.gradcheck``; the
engine-vs-exact probe harness in ``repro.zo.gradquality``.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.zo import estimator as _estimator


def spsa_grad(params, cfg: ArchConfig, batch: dict, key, eps: float = 1e-3):
    """MeZO gradient estimate over LoRA params: ((L+ − L−)/2ε) · z."""
    return _estimator.spsa_grad(params, cfg, batch, key, eps=eps)


def train_step(params, cfg: ArchConfig, batch: dict, key, lr: float,
               eps: float = 1e-3):
    return _estimator.train_step(params, cfg, batch, key, lr, eps)
