"""Pure-JAX FlashAttention with a hand-derived (structured) backward.

This is the paper's tensor-lifecycle discipline applied to attention
(paper §2 cites FlashAttention as the same recompute-over-store principle):

* forward: online-softmax over KV chunks; residuals are **(q, k, v, out,
  logsumexp)** — the [Nq, Nk] probability matrix never exists in HBM.
* backward: per (q-chunk, k-chunk) tile, probabilities are recomputed from
  the saved logsumexp, used, and discarded (Appendix A.2 eqs 17–21 tile-wise).

The q-chunk loop is a *Python* loop, so causal/windowed chunk ranges are
static: a causal q-chunk only ever visits k-chunks ``<= `` its own index, and
a sliding-window chunk visits O(window/chunk) k-chunks. This halves the
executed FLOPs for causal attention and makes windowed attention (gemma3,
recurrentgemma local layers) linear in sequence length — directly visible in
``cost_analysis()``.

Serves as the reference implementation for ``kernels/flash_attention.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # avoid -inf NaNs on fully-masked tiles


def _chunk_range(qc: int, n_kc: int, q_chunk: int, k_chunk: int,
                 window: int, causal: bool):
    """Static [lo, hi) k-chunk range visible to q-chunk qc."""
    q_lo, q_hi = qc * q_chunk, (qc + 1) * q_chunk - 1
    hi = n_kc
    if causal:
        hi = min(hi, q_hi // k_chunk + 1)
    lo = 0
    if window > 0:
        lo = max(0, (q_lo - window + 1) // k_chunk)
    return lo, hi


def _tile_mask(q_pos, k_pos, window: int, causal: bool):
    d = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(d.shape, jnp.bool_)
    if causal:
        ok = ok & (d >= 0)
    if window > 0:
        ok = ok & (d < window)
    return ok


def _pad_seq(x, mult: int, axis: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fwd_impl(q, k, v, window, causal, q_chunk, k_chunk):
    """Returns (out, lse). q:[B,Hkv,G,Nq,D] k,v:[B,Hkv,Nk,D]."""
    B, Hkv, G, Nq, D = q.shape
    Nk = k.shape[2]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    n_qc = -(-Nq // q_chunk)
    n_kc = -(-Nk // k_chunk)
    qf = q
    kf = _pad_seq(k, k_chunk, 2)
    vf = _pad_seq(v, k_chunk, 2)
    f32 = dict(preferred_element_type=jnp.float32)

    outs, lses = [], []
    for qc in range(n_qc):
        qs = qc * q_chunk
        qlen = min(q_chunk, Nq - qs)
        qi = jax.lax.dynamic_slice_in_dim(qf, qs, qlen, axis=3)
        q_pos = jnp.arange(qlen) + qs
        lo, hi = _chunk_range(qc, n_kc, q_chunk, k_chunk, window, causal)

        m = jnp.full((B, Hkv, G, qlen), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, G, qlen), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, qlen, D), jnp.float32)

        def body(carry, kc):
            m, l, acc = carry
            ks = kc * k_chunk
            ki = jax.lax.dynamic_slice_in_dim(kf, ks, k_chunk, axis=2)
            vi = jax.lax.dynamic_slice_in_dim(vf, ks, k_chunk, axis=2)
            k_pos = jnp.arange(k_chunk) + ks
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki, **f32) * scale
            ok = _tile_mask(q_pos, k_pos, window, causal) & (k_pos < Nk)[None, :]
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, -1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vi.dtype), vi, **f32)
            return (m_new, l_new, acc_new), None

        if hi > lo:
            (m, l, acc), _ = jax.lax.scan(body, (m, l, acc), jnp.arange(lo, hi))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        outs.append(out)
        lses.append(lse)
    out = jnp.concatenate(outs, axis=3)
    lse = jnp.concatenate(lses, axis=3)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, window: int = 0, causal: bool = True,
                    q_chunk: int = 1024, k_chunk: int = 1024):
    """FlashAttention. q:[B,H,Nq,D], k/v:[B,Hkv,Nk,D] (GQA) -> [B,H,Nq,D]."""
    B, H, Nq, D = q.shape
    Hkv = k.shape[1]
    qg = q.reshape(B, Hkv, H // Hkv, Nq, D)
    out, _ = _fwd_impl(qg, k, v, window, causal,
                       min(q_chunk, Nq), min(k_chunk, k.shape[2]))
    return out.reshape(B, H, Nq, D)


def _flash_fwd(q, k, v, window, causal, q_chunk, k_chunk):
    B, H, Nq, D = q.shape
    Hkv = k.shape[1]
    qg = q.reshape(B, Hkv, H // Hkv, Nq, D)
    out, lse = _fwd_impl(qg, k, v, window, causal,
                         min(q_chunk, Nq), min(k_chunk, k.shape[2]))
    return out.reshape(B, H, Nq, D), (q, k, v, out, lse)


def _flash_bwd(window, causal, q_chunk, k_chunk, res, g):
    q, k, v, out, lse = res
    B, H, Nq, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    Nk = k.shape[2]
    q_chunk = min(q_chunk, Nq)
    k_chunk = min(k_chunk, Nk)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    n_qc = -(-Nq // q_chunk)
    n_kc = -(-Nk // k_chunk)

    f32 = dict(preferred_element_type=jnp.float32)
    qf = q.reshape(B, Hkv, G, Nq, D)
    kf = _pad_seq(k, k_chunk, 2)
    vf = _pad_seq(v, k_chunk, 2)
    gf = g.reshape(B, Hkv, G, Nq, D).astype(q.dtype)
    of = out.reshape(B, Hkv, G, Nq, D)
    # delta_i = sum_d g_i * out_i  (the flash-bwd softmax correction term —
    # the tile-local form of A.2 eq 19's  sum(dprobs ⊙ probs))
    delta = jnp.sum(gf.astype(jnp.float32) * of.astype(jnp.float32), -1)

    dq = jnp.zeros(qf.shape, jnp.float32)
    dk = jnp.zeros(kf.shape, jnp.float32)
    dv = jnp.zeros(vf.shape, jnp.float32)

    for qc in range(n_qc):
        qs = qc * q_chunk
        qlen = min(q_chunk, Nq - qs)
        qi = jax.lax.dynamic_slice_in_dim(qf, qs, qlen, 3)
        gi = jax.lax.dynamic_slice_in_dim(gf, qs, qlen, 3)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, qs, qlen, 3)
        delta_i = jax.lax.dynamic_slice_in_dim(delta, qs, qlen, 3)
        q_pos = jnp.arange(qlen) + qs
        lo, hi = _chunk_range(qc, n_kc, q_chunk, k_chunk, window, causal)
        if hi <= lo:
            continue

        dqi = jnp.zeros(qi.shape, jnp.float32)

        def body(carry, kc):
            dqi, dk, dv = carry
            ks = kc * k_chunk
            ki = jax.lax.dynamic_slice_in_dim(kf, ks, k_chunk, 2)
            vi = jax.lax.dynamic_slice_in_dim(vf, ks, k_chunk, 2)
            k_pos = jnp.arange(k_chunk) + ks
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki, **f32) * scale
            ok = _tile_mask(q_pos, k_pos, window, causal) & (k_pos < Nk)[None, :]
            s = jnp.where(ok, s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])              # recomputed probs
            pl = p.astype(q.dtype)
            dvi = jnp.einsum("bhgqk,bhgqd->bhkd", pl, gi, **f32)  # eq 17
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", gi, vi, **f32)   # eq 18
            ds = p * (dp - delta_i[..., None]) * scale     # eq 19 (+ 1/sqrt(d))
            dsl = ds.astype(q.dtype)
            dqi = dqi + jnp.einsum("bhgqk,bhkd->bhgqd", dsl, ki, **f32)  # eq 20
            dki = jnp.einsum("bhgqk,bhgqd->bhkd", dsl, qi, **f32)         # eq 21
            dk_new = jax.lax.dynamic_update_slice_in_dim(
                dk, jax.lax.dynamic_slice_in_dim(dk, ks, k_chunk, 2) + dki, ks, 2)
            dv_new = jax.lax.dynamic_update_slice_in_dim(
                dv, jax.lax.dynamic_slice_in_dim(dv, ks, k_chunk, 2) + dvi, ks, 2)
            return (dqi, dk_new, dv_new), None

        (dqi, dk, dv), _ = jax.lax.scan(body, (dqi, dk, dv), jnp.arange(lo, hi))
        dq = jax.lax.dynamic_update_slice_in_dim(dq, dqi, qs, 3)

    dq = dq.reshape(B, H, Nq, D).astype(q.dtype)
    dk = dk[:, :, :Nk]
    dv = dv[:, :, :Nk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
