"""MeBP baseline (paper §3.3): gradient checkpointing + framework autodiff.

Identical model, identical per-block checkpointing — but every inner op runs
under the ``plain`` ExecutionPolicy backend (ordinary jnp), so the *framework* decides which tensors
to retain during each block's backward: ``h = x@A`` is materialized, the
attention probability matrix is materialized, normalized activations are
saved, etc. The memory gap between this and MeSP is exactly the paper's
Table 1/2/4 measurement (reproduced via ``compiled.memory_analysis()`` in
benchmarks/).
"""
from __future__ import annotations

import jax

from repro.api.policy import PLAIN
from repro.configs.base import ArchConfig
from repro.core import mesp
from repro.models import model as model_lib


def value_and_grad(params, cfg: ArchConfig, batch: dict):
    return mesp.value_and_grad(params, cfg, batch, policy=PLAIN)


def train_step(params, cfg: ArchConfig, batch: dict, lr: float):
    return mesp.train_step(params, cfg, batch, lr, policy=PLAIN)
