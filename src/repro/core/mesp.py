"""MeSP training engines (paper §4).

Two forms, both computing *mathematically identical* gradients:

1. :func:`value_and_grad` — production engine. The model's scan-over-blocks
   already stores only block inputs (``jax.checkpoint`` per block) and every
   inner op is a hand-derived ``custom_vjp`` (``core.structured``; with the
   ``pallas`` backend the same rules fused into Pallas TPU kernels via
   ``kernels.ops``), so a single ``jax.grad`` call executes exactly the
   paper's recompute schedule.
   LoRA gradients are accumulated and applied once per step — for SGD this is
   identical to the paper's immediate per-block update because LoRA params are
   disjoint across blocks (verified in tests/test_mesp_equivalence.py).

2. :func:`sequential_train_step` — the paper's §4.3 algorithm verbatim:
   a Python reverse loop over blocks, each block recomputed from its stored
   input, gradients computed via the structured VJPs, and **the optimizer
   applied immediately** before the next block's backward. Registered as the
   first-class ``mesp_seq`` engine (``repro.api``); also used by the
   reproduction benchmarks and the convergence example (dense family).

Execution regime selection is an :class:`repro.api.policy.ExecutionPolicy`
(``policy=``). The legacy ``mode=``/``act_spec=`` string kwargs are still
accepted here — and only here — as a convenience for tests/notebooks; they
are folded into a policy at this boundary and everything below
(``models/*``, ``kernels/*``) takes the policy object exclusively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.policy import ExecutionPolicy
from repro.configs.base import ArchConfig
from repro.core import structured
from repro.models import layers, model as model_lib

Array = jax.Array


def _resolve_policy(policy: Optional[ExecutionPolicy], mode: Optional[str],
                    act_spec) -> ExecutionPolicy:
    if policy is None:
        return ExecutionPolicy.from_mode(mode, act_spec=act_spec)
    if mode is not None or act_spec is not None:
        raise TypeError("pass either policy= or the legacy mode=/act_spec= "
                        "kwargs, not both")
    return policy


# ---------------------------------------------------------------------------
# production engine
# ---------------------------------------------------------------------------


def value_and_grad(params, cfg: ArchConfig, batch: dict, *,
                   policy: Optional[ExecutionPolicy] = None,
                   mode: Optional[str] = None, act_spec=None):
    """(loss, grads-over-LoRA-params). grads tree has None at frozen leaves."""
    policy = _resolve_policy(policy, mode, act_spec)
    train, frozen = model_lib.split_params(params)

    def f(train):
        p = model_lib.merge_params(train, frozen)
        return model_lib.loss_fn(p, cfg, batch, policy=policy)

    return jax.value_and_grad(f)(train)


def train_step(params, cfg: ArchConfig, batch: dict, lr: float, *,
               policy: Optional[ExecutionPolicy] = None,
               mode: Optional[str] = None, act_spec=None):
    """One SGD step over LoRA params. Returns (params, loss)."""
    loss, grads = value_and_grad(params, cfg, batch,
                                 policy=_resolve_policy(policy, mode,
                                                        act_spec))
    new = jax.tree_util.tree_map(
        lambda p, g: p if g is None else (p - lr * g.astype(p.dtype)),
        params, grads,
        is_leaf=lambda x: x is None)
    return new, loss


# ---------------------------------------------------------------------------
# faithful §4.3 engine: layer-by-layer with immediate optimizer update
# (dense family — the paper's Qwen2.5 models)
# ---------------------------------------------------------------------------


def _unstack(tree, n):
    return [jax.tree_util.tree_map(lambda t: t[i], tree) for i in range(n)]


def _restack(trees):
    return jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *trees)


def _sgd_lora(bp, gbp, lr):
    """Immediate SGD on the LoRA leaves of one block."""
    mask = model_lib.trainable_mask(bp)
    return jax.tree_util.tree_map(
        lambda p, g, m: (p - lr * g.astype(p.dtype)) if m else p,
        bp, gbp, mask)


def sequential_train_step(params, cfg: ArchConfig, batch: dict, lr: float,
                          *, policy: Optional[ExecutionPolicy] = None,
                          mode: Optional[str] = None):
    """Paper §4.3: forward stores only block inputs; backward walks blocks in
    reverse, recomputes each block, computes its LoRA grads and updates them
    *immediately*. Dense-family only. Returns (new_params, loss).
    """
    assert cfg.family == "dense" and not cfg.window_pattern
    policy = _resolve_policy(policy, mode, None)
    L = cfg.n_layers
    blocks = _unstack(params["blocks"], L)

    def block_f(bp, x):
        return model_lib.dense_block(bp, x, cfg, policy=policy)[0]

    # ---- Forward Phase: store only block inputs (checkpoint dict) ----------
    x = layers.embed(params["embed"], batch["tokens"], cfg)
    checkpoints = []
    for bp in blocks:
        checkpoints.append(x)
        x = block_f(bp, x)

    # ---- head: loss + gradient w.r.t. the last block output ---------------
    def head(x):
        xn = layers.norm(params["final_norm"], x, cfg, policy=policy)
        logits = layers.unembed(params["embed"], xn, cfg)
        return structured.softmax_xent(logits, batch["labels"])

    loss, head_vjp = jax.vjp(head, x)
    (g,) = head_vjp(jnp.ones((), loss.dtype))

    # ---- Backward Phase: reverse loop, recompute, update immediately ------
    new_blocks = [None] * L
    for i in reversed(range(L)):
        _, blk_vjp = jax.vjp(block_f, blocks[i], checkpoints[i])  # recompute
        gbp, g = blk_vjp(g)
        new_blocks[i] = _sgd_lora(blocks[i], gbp, lr)
        # gbp / intermediates die here — nothing from block i survives the
        # iteration (the paper's "explicitly deallocate and clear cache").

    new_params = dict(params)
    new_params["blocks"] = _restack(new_blocks)
    return new_params, loss
